"""The unified OCC engine: single-compiled-call epoch loop (zero per-epoch
host transfers), overflow surfacing, and the streaming partial_fit surface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CenterPool, OCCEngine, DPMeansTransaction, OFLTransaction,
    BPMeansTransaction, make_pool, nearest_center,
    occ_dp_means, occ_ofl,
)
from repro.core._reference import _reference_validate
from repro.core import engine as engine_mod
from repro.data import dp_stick_breaking_data

LAM = 4.0


# ------------------------------------------------------------------ one jit

def test_pass_is_one_compiled_call_no_per_epoch_transfers():
    """A multi-epoch pass is ONE trace and ONE dispatch; OCCStats come back
    as device arrays from that call — the legacy drivers dispatched T
    compiled epochs and forced a device->host int() sync per epoch."""
    # distinctive shapes so no other test has warmed this cache entry
    x, _, _ = dp_stick_breaking_data(488, seed=11, dim=12)
    x = jnp.asarray(x)
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=99), pb=61)
    t_epochs = -(-488 // 61)

    traces0 = engine_mod._PASS_TRACES
    res = eng.run(x)
    assert eng.n_dispatches == 1
    assert engine_mod._PASS_TRACES - traces0 == 1   # epoch loop inside 1 jit

    # stats for all epochs are device arrays out of the single call
    assert isinstance(res.stats.proposed, jax.Array)
    assert isinstance(res.stats.accepted, jax.Array)
    assert res.stats.proposed.shape == (t_epochs,)
    assert isinstance(res.assign, jax.Array) and isinstance(res.send, jax.Array)

    # a second pass with identical shapes reuses the compilation
    eng.run(x)
    assert eng.n_dispatches == 2
    assert engine_mod._PASS_TRACES - traces0 == 1


def test_engine_matches_wrapper():
    """The convenience wrapper is a thin shim: engine + refine == occ_dp_means."""
    x, _, _ = dp_stick_breaking_data(512, seed=3)
    x = jnp.asarray(x)
    txn = DPMeansTransaction(LAM, k_max=128)
    eng = OCCEngine(txn, pb=64)
    res = eng.run(x)
    pool = txn.refine(res.pool, x, res.assign)
    ref = occ_dp_means(x, LAM, pb=64, k_max=128, max_iters=1)
    assert np.array_equal(np.asarray(res.assign), np.asarray(ref.z))
    np.testing.assert_array_equal(np.asarray(pool.centers),
                                  np.asarray(ref.pool.centers))
    assert np.array_equal(np.asarray(res.stats.proposed),
                          np.asarray(ref.stats.proposed))


# ----------------------------------------------------------------- overflow

def test_bounded_master_sent_overflow_flag():
    """cap < #sent proposals -> sent_overflow raised; proposals beyond the
    cap are dropped (slot -1), the first `cap` validated in index order.
    (Compaction semantics shared by the reference and the engine path —
    see test_validator_equivalence for the fast-path equivalents.)"""
    pool = make_pool(16, 2)
    pts = jnp.asarray(np.eye(8, 2, dtype=np.float32) * 100
                      + np.arange(8, dtype=np.float32)[:, None] * 50)
    send = jnp.ones((8,), bool)

    def accept_fn(pool, x_j, aux_j):
        d2, ref = nearest_center(pool, x_j)
        return d2 > 1.0, x_j, ref

    pool2, slots, _, ovf = _reference_validate(pool, send, pts, accept_fn,
                                               cap=3)
    assert bool(ovf)
    assert int(pool2.count) == 3
    assert np.array_equal(np.asarray(slots[:3]), [0, 1, 2])
    assert (np.asarray(slots[3:]) == -1).all()

    # cap not exceeded -> no flag, identical to the unbounded validator
    send2 = send.at[3:].set(False)
    pool3, slots3, _, ovf2 = _reference_validate(pool, send2, pts, accept_fn,
                                                 cap=3)
    assert not bool(ovf2)
    assert int(pool3.count) == 3


def test_sent_overflow_propagates_to_pool_through_engine():
    """The engine surfaces validate_cap overflow on pool.overflow even when
    the pool itself has spare capacity."""
    x, _, _ = dp_stick_breaking_data(256, seed=6)
    x = jnp.asarray(x)
    # epoch 1 sends everything (empty pool); cap=8 << pb=64 overflows
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=256), pb=64, validate_cap=8)
    res = eng.run(x)
    assert bool(res.pool.overflow)
    assert int(res.pool.count) < 256          # pool capacity NOT the cause
    # stats still count what was optimistically sent
    assert int(res.stats.proposed[0]) == 64


def test_pool_capacity_overflow_through_engine():
    """CenterPool.overflow rises when validated accepts exceed k_max."""
    x, _, _ = dp_stick_breaking_data(256, seed=6)
    eng = OCCEngine(DPMeansTransaction(0.01, k_max=8), pb=64)
    res = eng.run(jnp.asarray(x))
    assert bool(res.pool.overflow)
    assert int(res.pool.count) == 8


# ---------------------------------------------------------------- streaming

def test_partial_fit_stream_equals_batch_dp():
    """Streaming epochs over arriving batches == the one-shot batch pass
    (same pool evolution, same assignments, same stats)."""
    x, _, _ = dp_stick_breaking_data(512, seed=4)
    x = jnp.asarray(x)
    txn = DPMeansTransaction(LAM, k_max=128)

    batch = occ_dp_means(x, LAM, pb=64, k_max=128, max_iters=1)

    eng = OCCEngine(txn, pb=64)
    zs = [eng.partial_fit(x[i:i + 128]).assign for i in range(0, 512, 128)]
    z_stream = np.concatenate([np.asarray(z) for z in zs])

    assert eng.n_seen == 512
    assert int(eng.pool.count) == int(batch.pool.count)
    assert np.array_equal(z_stream, np.asarray(batch.z))
    # note: batch.pool went through refine(); compare pre-refine via stats
    assert np.array_equal(np.asarray(eng.stats.proposed),
                          np.asarray(batch.stats.proposed))
    assert np.array_equal(np.asarray(eng.stats.accepted),
                          np.asarray(batch.stats.accepted))


def test_partial_fit_stream_equals_batch_ofl_bitexact():
    """OFL's counter-based uniforms are keyed on the global point index, so
    the stream reproduces the one-shot run draw-for-draw (App. B.3)."""
    x, _, _ = dp_stick_breaking_data(384, seed=5)
    x = jnp.asarray(x)
    key = jax.random.key(9)
    batch = occ_ofl(x, LAM, pb=64, key=key, k_max=256)

    eng = OCCEngine(OFLTransaction(LAM, 256, key), pb=64)
    zs = [eng.partial_fit(x[i:i + 64]).assign for i in range(0, 384, 64)]
    assert np.array_equal(np.concatenate([np.asarray(z) for z in zs]),
                          np.asarray(batch.z))
    k = int(batch.pool.count)
    np.testing.assert_array_equal(np.asarray(eng.pool.centers[:k]),
                                  np.asarray(batch.pool.centers[:k]))


def test_partial_fit_stats_accumulate_on_device():
    x, _, _ = dp_stick_breaking_data(256, seed=8)
    x = jnp.asarray(x)
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=64), pb=32)
    assert eng.stats.proposed.shape == (0,)
    eng.partial_fit(x[:128])
    eng.partial_fit(x[128:])
    assert isinstance(eng.stats.proposed, jax.Array)
    assert eng.stats.proposed.shape == (8,)      # 2 batches x 4 epochs
    eng.reset_stream()
    assert eng.pool is None and eng.n_seen == 0


def test_bp_transaction_through_engine():
    """BP-means runs through the same engine (feature pool, (N,K) assigns)."""
    from repro.data import bp_stick_breaking_data
    xb, _, _ = bp_stick_breaking_data(128, seed=2)
    xb = jnp.asarray(xb)
    txn = BPMeansTransaction(LAM, k_max=32)
    eng = OCCEngine(txn, pb=32)
    res = eng.run(xb)
    assert res.assign.shape == (128, 32) and res.assign.dtype == bool
    assert isinstance(res.pool, CenterPool)
    assert res.stats.proposed.shape == (4,)

"""Train/serve split: snapshot store + batched cluster-assignment service.

Contracts under test (DESIGN.md §10):
  * snapshot freeze/round-trip — capacity bucketing, prefix mask, overflow
    propagation, publication through the engine's `publish=` hook;
  * serve == train — service responses bit-identical to engine labels
    (`nearest_center` on the same snapshot's pool), per version;
  * hot-swap — responses tagged with the producing version, versions
    monotone, swapping never retraces a warm (bucket, capacity) cache;
  * bucket policy — ragged request sizes pad to power-of-two buckets and
    padding rows can never alias a real answer (hypothesis layer).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DPMeansTransaction, OCCEngine, nearest_center
from repro.data import dp_stick_breaking_data
from repro.kernels import ops
from repro.serving import (
    ClusterService, ModelSnapshot, Query, ServeConfig, SnapshotStore,
    freeze_snapshot, next_bucket,
)
from repro.serving import cluster_service as cs_mod

LAM = 4.0


def _stream(n=768, seed=0, dim=8):
    x, _, _ = dp_stick_breaking_data(n, seed=seed, dim=dim)
    return jnp.asarray(x)


def _trained_store(x, pb=64, k_max=128, batches=((0, 300), (300, 768))):
    store = SnapshotStore(capacity=64)
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=k_max), pb=pb,
                    publish=store.publish_pass)
    for lo, hi in batches:
        eng.partial_fit(x[lo:hi])
    eng.flush()
    return store, eng


# ------------------------------------------------------------- snapshots

def test_freeze_snapshot_capacity_bucketing_and_prefix():
    x = _stream()
    _, eng = _trained_store(x)
    snap = freeze_snapshot(eng.pool, version=7, n_seen=eng.n_processed)
    k = int(eng.pool.count)
    assert snap.version == 7 and snap.count == k
    assert snap.capacity == next_bucket(k) and snap.capacity >= k
    assert snap.capacity & (snap.capacity - 1) == 0
    # prefix compaction preserves the live centers exactly
    np.testing.assert_array_equal(np.asarray(snap.centers[:k]),
                                  np.asarray(eng.pool.centers[:k]))
    assert np.array_equal(np.asarray(snap.mask), np.arange(snap.capacity) < k)
    # as_pool round-trips into the engine-side primitive
    d2s, ids = nearest_center(snap.as_pool(), x[:50], backend="ref")
    d2e, ide = nearest_center(eng.pool, x[:50], backend="ref")
    assert np.array_equal(np.asarray(ids), np.asarray(ide))
    np.testing.assert_array_equal(np.asarray(d2s), np.asarray(d2e))


def test_snapshot_overflow_epoch_roundtrip():
    """Publishing through a pool-overflow epoch surfaces overflow on the
    snapshot; the snapshot stays servable (full capacity, valid prefix)."""
    x = _stream()
    store = SnapshotStore()
    eng = OCCEngine(DPMeansTransaction(0.01, k_max=8), pb=64,
                    publish=store.publish_pass)
    eng.partial_fit(x[:256])
    snap = store.latest()
    assert snap.overflow and snap.count == 8 and snap.capacity == 8
    svc = ClusterService(store, backend="ref")
    resp = svc.assign(x[:16])
    assert resp.version == snap.version
    assert (resp.labels >= 0).all() and (resp.labels < 8).all()


def test_engine_publish_hook_stream_metadata():
    """One version per committed pass; carry-only calls publish nothing;
    flush publishes the final short epoch; metadata tracks the stream."""
    x = _stream()
    store = SnapshotStore()
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64,
                    publish=store.publish_pass)
    eng.partial_fit(x[:30])                  # carry only -> no version
    assert len(store) == 0 and eng.n_pending == 30
    eng.partial_fit(x[30:300])               # commits 4 epochs, carries 44
    assert len(store) == 1
    assert store.latest().n_seen == 256 and store.latest().epochs == 4
    eng.partial_fit(x[300:750])              # commits 7 more, carries 46
    assert len(store) == 2 and eng.n_pending == 46
    eng.flush()                              # final short epoch
    assert len(store) == 3
    assert store.latest().n_seen == 750 and store.latest().epochs == 12
    versions = store.versions()
    assert versions == sorted(versions)
    # published pool == streaming pool at each publish point (last one)
    np.testing.assert_array_equal(
        np.asarray(store.latest().centers[:store.latest().count]),
        np.asarray(eng.pool.centers[:int(eng.pool.count)]))


def test_store_ring_eviction_keeps_monotone_versions():
    x = _stream(256)
    store = SnapshotStore(capacity=2)
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=64), pb=32,
                    publish=store.publish_pass)
    for i in range(0, 256, 64):
        eng.partial_fit(x[i:i + 64])
    assert len(store) == 2
    assert store.versions() == [3, 4]        # FIFO eviction, monotone ids
    assert store.get(1) is None and store.get(4) is not None
    assert store.latest().version == 4


# ------------------------------------------------------ serve == train

def test_service_assign_bit_identical_to_engine_labels():
    x = _stream()
    store, eng = _trained_store(x)
    svc = ClusterService(store, backend="ref")
    resp = svc.score(x[:100])
    snap = store.get(resp.version)
    d2e, ide = nearest_center(snap.as_pool(), x[:100], backend="ref")
    assert np.array_equal(resp.labels, np.asarray(ide))
    assert resp.labels.dtype == np.int32
    # scores are the squared distances of the assigned centers
    np.testing.assert_allclose(resp.scores, np.asarray(d2e), atol=1e-5)


def test_service_response_replayable_from_tagged_version():
    """Zero stale reads: the tagged snapshot reproduces the response
    bit-exactly through the service's own jitted step."""
    x = _stream()
    store, eng = _trained_store(x)
    svc = ClusterService(store, backend="ref")
    resp = svc.score(x[:77])
    snap = store.get(resp.version)
    qp = jnp.concatenate(
        [x[:77], jnp.zeros((resp.bucket - 77, x.shape[1]), x.dtype)], 0)
    d2, idx = cs_mod._assign_step(snap.centers, snap.mask,
                                  np.int32(snap.count), qp, np.int32(77),
                                  backend="ref")
    assert np.array_equal(resp.labels, np.asarray(idx[:77]))
    np.testing.assert_array_equal(resp.scores, np.asarray(d2[:77]))


def test_hot_swap_between_microbatches_no_retrace():
    """New versions are picked up between microbatches; a version change
    within the same (bucket, capacity) never recompiles the query step."""
    x = _stream()
    store = SnapshotStore()
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64,
                    publish=store.publish_pass)
    eng.partial_fit(x[:256])
    svc = ClusterService(store, backend="ref")
    r1 = svc.assign(x[:40])
    v1 = r1.version
    eng.partial_fit(x[256:512])              # publishes a newer version
    # republish the same pool shape to pin the capacity bucket, then prove
    # a pure version change is free: same (bucket, capacity) -> no retrace
    svc.assign(x[:40])                       # may retrace if capacity grew
    traces0 = cs_mod._QUERY_TRACES
    store.publish_pool(eng.pool)
    r2 = svc.assign(x[:40])
    assert r2.version > v1
    assert svc.n_swaps >= 2
    assert cs_mod._QUERY_TRACES == traces0   # warm cache across the swap
    # the old version still audits against its own snapshot
    old = store.get(v1)
    _, ide = nearest_center(old.as_pool(), x[:40], backend="ref")
    assert np.array_equal(r1.labels, np.asarray(ide))
    assert svc.n_dispatches == svc.n_microbatches


def test_topk_and_score_coherence():
    x = _stream()
    store, _ = _trained_store(x)
    svc = ClusterService(store, backend="ref")
    k = min(4, store.latest().count)
    rt = svc.topk(x[:25], k=k)
    ra = svc.score(x[:25])
    assert rt.labels.shape == (25, k)
    assert np.array_equal(rt.labels[:, 0], ra.labels)     # top-1 == assign
    np.testing.assert_array_equal(rt.scores[:, 0], ra.scores)
    assert (np.diff(rt.scores, axis=1) >= 0).all()        # ascending
    # matches a full sort of the reference distance matrix
    snap = store.get(rt.version)
    d2, idx = ops.serve_topk(x[:25], snap.centers, k, mask=snap.mask,
                             count=jnp.asarray(snap.count, jnp.int32))
    assert np.array_equal(rt.labels, np.asarray(idx))


def test_service_with_mesh_replicated_snapshot():
    """The mesh serving path (replicated snapshot + data-sharded queries)
    compiles and stays bit-identical to the meshless service.  One-device
    mesh here; the multi-device placement is the same GSPMD program (see
    shardings.serve_snapshot_sharding / serve_query_sharding)."""
    from repro.launch.mesh import compat_mesh
    x = _stream()
    store, _ = _trained_store(x)
    mesh = compat_mesh((1,), ("data",))
    svc_m = ClusterService(store, backend="ref", mesh=mesh)
    svc_0 = ClusterService(store, backend="ref")
    rm, r0 = svc_m.score(x[:48]), svc_0.score(x[:48])
    assert rm.version == r0.version
    assert np.array_equal(rm.labels, r0.labels)
    np.testing.assert_array_equal(rm.scores, r0.scores)
    tm = svc_m.topk(x[:16], k=2)
    assert np.array_equal(tm.labels, svc_0.topk(x[:16], k=2).labels)


def test_service_no_version_raises():
    svc = ClusterService(SnapshotStore(), backend="ref")
    with pytest.raises(RuntimeError):
        svc.assign(jnp.zeros((4, 8)))


def test_giant_request_splits_with_single_version():
    x = _stream()
    store, _ = _trained_store(x)
    svc = ClusterService(store, backend="ref", max_bucket=128)
    before = svc.n_microbatches
    resp = svc.score(x[:300])                # 3 microbatches of <=128
    assert resp.labels.shape == (300,)
    assert svc.n_microbatches - before == 3
    snap = store.get(resp.version)
    _, ide = nearest_center(snap.as_pool(), x[:300], backend="ref")
    assert np.array_equal(resp.labels, np.asarray(ide))


# --------------------------------------------------------- bucket policy

def test_bucket_rounding_and_padding_mask():
    x = _stream()
    store, _ = _trained_store(x)
    svc = ClusterService(store, backend="ref", min_bucket=8, max_bucket=256)
    for n, want in [(1, 8), (8, 8), (9, 16), (100, 128), (256, 256)]:
        resp = svc.assign(x[:n])
        assert resp.bucket == want, (n, resp.bucket)
        assert resp.labels.shape == (n,)
        assert (resp.labels >= 0).all()      # padding never leaks out


def test_bucketed_emulation_parity_on_serving_shapes():
    """The vmapped emulation harness parity-checks a production serving
    bucket (4096 queries x 512-capacity snapshot) against the jnp oracle —
    the shape interpret mode cannot sweep in CI time."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(4096, 32)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(512, 32)).astype(np.float32))
    count = 301
    m = jnp.asarray(np.arange(512) < count)
    d2e, ie = ops.serve_assign(x, c, m, count=jnp.asarray(count, jnp.int32),
                               n_valid=jnp.asarray(4000, jnp.int32),
                               backend="emulate")
    d2r, ir = ops.serve_assign(x, c, m, count=jnp.asarray(count, jnp.int32),
                               n_valid=jnp.asarray(4000, jnp.int32),
                               backend="ref")
    assert np.array_equal(np.asarray(ie), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(d2e[:4000]), np.asarray(d2r[:4000]),
                               atol=1e-3)
    assert (np.asarray(ie[4000:]) == -1).all()
    assert np.isinf(np.asarray(d2e[4000:])).all()


# -------------------------------------------------------- hypothesis layer

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(sizes=st.lists(st.integers(min_value=1, max_value=200),
                          min_size=1, max_size=6))
    def test_hypothesis_ragged_requests_parity(sizes):
        """Any sequence of ragged request sizes: every response's labels
        match the engine labels on its tagged version, buckets are powers
        of two >= the request, and version tags are monotone."""
        x = _stream(512, seed=7)
        store, _ = _trained_store(x, batches=((0, 512),))
        svc = ClusterService(store, backend="ref", min_bucket=8,
                             max_bucket=256)
        rng = np.random.default_rng(11)
        last_v = -1
        for n in sizes:
            lo = int(rng.integers(0, 512 - n)) if n < 512 else 0
            resp = svc.score(x[lo:lo + n])
            assert resp.bucket >= min(n, 256)
            assert resp.bucket & (resp.bucket - 1) == 0
            assert resp.version >= last_v
            last_v = resp.version
            snap = store.get(resp.version)
            _, ide = nearest_center(snap.as_pool(), x[lo:lo + n],
                                    backend="ref")
            assert np.array_equal(resp.labels, np.asarray(ide))
else:  # pragma: no cover - exercised only without hypothesis
    def test_hypothesis_layer_skipped():
        pytest.skip("hypothesis not installed; deterministic layer still ran")


# ------------------------------- hierarchical layout + multi-probe (§16)

def _hier_store(x, pb=64, k_max=128, batches=((0, 300), (300, 768)),
                lam=1.0, **hier_kw):
    # lam=1.0 grows the pool to ~128 centers (16 coarse cells) — enough
    # cells that a small probe width actually prunes; LAM=4 yields 4
    # centers / 2 cells, where probes >= n_cells degenerates to flat.
    store = SnapshotStore(capacity=64, hier=True, **hier_kw)
    eng = OCCEngine(DPMeansTransaction(lam, k_max=k_max), pb=pb,
                    publish=store.publish_pass)
    for lo, hi in batches:
        eng.partial_fit(x[lo:hi])
    eng.flush()
    return store, eng


def test_hier_build_invariants_and_flat_bit_identity():
    """The hierarchical layout is a pure access-path permutation: fine
    shards partition the active prefix [0, count) exactly once, every
    shard row is a bit-copy of its flat row, and the snapshot's FLAT
    buffers are bit-identical to a hier=False publish of the same pool."""
    x = _stream()
    store_h, eng = _hier_store(x)
    store_f = SnapshotStore(capacity=64)
    store_f.publish_pool(eng.pool)
    sh, sf = store_h.latest(), store_f.latest()
    np.testing.assert_array_equal(np.asarray(sh.centers),
                                  np.asarray(sf.centers))
    np.testing.assert_array_equal(np.asarray(sh.mask), np.asarray(sf.mask))
    h = sh.hier
    assert h is not None and sf.hier is None
    count = int(sh.count)
    assert h.n_cells & (h.n_cells - 1) == 0 and h.n_cells <= count
    assert h.shard_cap & (h.shard_cap - 1) == 0
    ids, msk = np.asarray(h.fine_ids), np.asarray(h.fine_mask)
    np.testing.assert_array_equal(np.sort(ids[msk]), np.arange(count))
    assert (ids[~msk] == -1).all()
    fine, flat = np.asarray(h.fine), np.asarray(sh.centers)
    r, c = np.nonzero(msk)
    np.testing.assert_array_equal(fine[r, c], flat[ids[r, c]])
    assert (fine[~msk] == 0).all()
    # coarse rows are bit-copies of active-prefix centers
    assert np.asarray(h.coarse_mask).all()
    coarse = np.asarray(h.coarse)
    assert all((coarse[i] == flat[:count]).all(1).any()
               for i in range(h.n_cells))


def test_hier_delta_store_materializes_same_layout():
    """Delta-mode stores build the hier at first materialize; the layout
    must equal the eager store's bit for bit (same builder, same prefix)."""
    x = _stream()
    store_h, eng = _hier_store(x)
    store_d = SnapshotStore(capacity=64, hier=True, delta=True)
    store_d.publish_pool(eng.pool)
    he = store_h.latest().hier
    hd = store_d.latest().materialize().hier if hasattr(
        store_d.latest(), "materialize") else store_d.latest().hier
    assert hd is not None
    np.testing.assert_array_equal(np.asarray(hd.fine_ids),
                                  np.asarray(he.fine_ids))
    np.testing.assert_array_equal(np.asarray(hd.fine), np.asarray(he.fine))
    np.testing.assert_array_equal(np.asarray(hd.coarse),
                                  np.asarray(he.coarse))


def test_service_multiprobe_p_all_bit_identical_to_flat():
    """The exactness contract: probes >= n_cells routes the FLAT step, so
    responses are bit-identical to a probes=None service — and a hier
    store serves plain flat queries unchanged."""
    x = _stream()
    store, _ = _hier_store(x)
    n_cells = store.latest().hier.n_cells
    flat = ClusterService(store, backend="ref", audit_log=True)
    pall = ClusterService(store, backend="ref", probes=n_cells,
                          audit_log=True)
    q = np.asarray(x[100:137])
    r_f, r_a = flat.topk(q, k=7), pall.topk(q, k=7)
    np.testing.assert_array_equal(r_f.labels, r_a.labels)
    np.testing.assert_array_equal(r_f.scores, r_a.scores)
    assert pall.audit[-1].probes == 0        # flat dispatch, by construction
    assert pall.metrics()["n_topk_multiprobe"] == 0


def test_service_multiprobe_counters_recall_and_audit_record():
    x = _stream()
    store, _ = _hier_store(x)
    h = store.latest().hier
    svc = ClusterService(store, backend="ref", probes=2,
                         recall_audit_every=2, audit_log=True)
    q = np.asarray(x[:40])
    for _ in range(4):
        resp = svc.topk(q, k=5)
    met = svc.metrics()
    assert met["n_topk_multiprobe"] == 4
    assert met["topk_probes"] == 2
    assert 0 < met["topk_shards_probed"] <= 4 * h.n_cells
    assert met["topk_tiles_skipped"] == 4 * h.n_cells - met["topk_shards_probed"]
    assert met["topk_recall_audits"] == 2    # every 2nd of 4 dispatches
    assert 0.0 < met["topk_recall"] <= 1.0
    assert svc.audit[-1].probes == 2
    # responses stay well-formed: valid ids in [0, count), ascending d2
    labels, scores = resp.labels, resp.scores
    assert ((labels >= -1) & (labels < int(store.latest().count))).all()
    valid = labels >= 0
    assert np.isfinite(scores[valid]).all()


def test_service_multiprobe_backend_parity_and_no_retrace():
    """ref and emulate services agree through the full multi-probe path
    (indices exactly, distances to f32 tolerance), and a version hot-swap
    does not retrace the warm multi-probe step."""
    x = _stream()
    store, eng = _hier_store(x)
    q = np.asarray(x[200:232])
    svc_r = ClusterService(store, backend="ref", probes=2)
    svc_e = ClusterService(store, backend="emulate", probes=2)
    r_r, r_e = svc_r.topk(q, k=6), svc_e.topk(q, k=6)
    np.testing.assert_array_equal(r_r.labels, r_e.labels)
    np.testing.assert_allclose(r_r.scores, r_e.scores, atol=1e-5)
    traces0 = cs_mod._QUERY_TRACES
    store.publish_pool(eng.pool)             # new version, same buckets
    r2 = svc_r.topk(q, k=6)
    assert cs_mod._QUERY_TRACES == traces0   # warm cache across versions
    assert r2.version > r_r.version


def test_service_probes_requires_hier_snapshot():
    x = _stream()
    store, _ = _trained_store(x)             # hier=False store
    svc = ClusterService(store, backend="ref", probes=2)
    with pytest.raises(RuntimeError, match="hier"):
        svc.topk(np.asarray(x[:8]), k=3)


# --------------------------------------------------- §17 typed surface

def test_shims_bit_identical_to_submit_query_solo():
    """`assign`/`score`/`topk` are pure shims over `submit(Query(...))`:
    every response field bit-identical on the solo path."""
    x = _stream()
    store, _ = _trained_store(x)
    svc = ClusterService(store, backend="ref")
    q = np.asarray(x[:33])
    pairs = [
        (svc.score(q), svc.submit(Query(q))),
        (svc.assign(q), svc.submit(Query(q, want_scores=False))),
        (svc.topk(q, k=5), svc.submit(Query(q, kind="topk", k=5))),
    ]
    for shim, typed in pairs:
        assert shim.version == typed.version
        assert shim.bucket == typed.bucket
        assert shim.group == typed.group == -1
        assert shim.degraded == typed.degraded is False
        np.testing.assert_array_equal(shim.labels, typed.labels)
        if shim.scores is None:
            assert typed.scores is None
        else:
            np.testing.assert_array_equal(shim.scores, typed.scores)


def test_shims_bit_identical_to_submit_query_coalesced():
    """Same identity through the admission queue: the shims land in the
    same (kind, k, lane) groups the typed form does."""
    x = _stream()
    store, _ = _trained_store(x)
    svc = ClusterService(store, backend="ref", coalesce=True,
                         coalesce_bucket=64, coalesce_delay_ms=5.0)
    try:
        q = np.asarray(x[:17])
        shim, typed = svc.score(q), svc.submit(Query(q))
        assert shim.version == typed.version
        assert shim.group >= 0 and typed.group >= 0
        np.testing.assert_array_equal(shim.labels, typed.labels)
        np.testing.assert_array_equal(shim.scores, typed.scores)
        tk = svc.submit(Query(q, kind="topk", k=4))
        np.testing.assert_array_equal(svc.topk(q, k=4).labels, tk.labels)
    finally:
        svc.close()


def test_serve_config_object_and_keyword_forms_agree():
    """`ClusterService(store, ServeConfig(...))` and the historical
    keyword form resolve to the same construction; keyword overrides
    patch a passed config."""
    x = _stream()
    store, _ = _trained_store(x)
    cfg = ServeConfig(backend="ref", min_bucket=16, coalesce_bucket=128)
    svc_a = ClusterService(store, cfg, max_bucket=256)
    svc_b = ClusterService(store, backend="ref", min_bucket=16,
                           coalesce_bucket=128, max_bucket=256)
    assert svc_a.config == svc_b.config
    assert (svc_a.backend, svc_a.min_bucket, svc_a.max_bucket) == \
        ("ref", 16, 256)
    assert svc_a.config.coalesce_bucket == 128
    q = np.asarray(x[:9])
    ra, rb = svc_a.score(q), svc_b.score(q)
    assert ra.version == rb.version and ra.bucket == rb.bucket == 16
    np.testing.assert_array_equal(ra.labels, rb.labels)

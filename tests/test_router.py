"""Serving scale-out: router, admission queue, delta publication (§12).

Contracts under test:
  * multi-model isolation — publishing to model A never changes model B's
    responses; per-model versions are independent and monotone;
  * shared jit caches — tenants with equal (bucket, capacity) shapes reuse
    ONE compilation (the router-level compile counter stays flat);
  * admission queue — coalesced responses are bit-identical to solo
    responses on the same tagged version; replay of the recorded dispatch
    reproduces every member bit-exactly; a lone request with a stalled
    partner is flushed at the deadline, never held past its budget;
  * delta publication — delta-materialized snapshots are bit-identical to
    the eager copies (incl. pool-overflow epochs), replication through the
    in-process channel reproduces every version bit-identically, and a
    rewritten prefix forces a rebase rather than a corrupt replica;
  * warm restore — `OCCEngine.restore` resumes a stream bit-identically
    and with the persisted adaptive cap (no full-width re-burn-in), and
    the cap trace reaches the serving metrics endpoint.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DPMeansTransaction, OCCEngine, nearest_center
from repro.data import dp_stick_breaking_data
from repro.distributed import DeltaChannel, make_follower
from repro.serving import (
    ClusterService, ModelRouter, Query, ServeConfig, SnapshotStore,
)
from repro.serving import cluster_service as cs_mod

LAM = 4.0


def _stream(n=768, seed=0, dim=8):
    x, _, _ = dp_stick_breaking_data(n, seed=seed, dim=dim)
    return jnp.asarray(x)


def _train_into(store_publish, x, lam=LAM, pb=64, k_max=128, **eng_kw):
    eng = OCCEngine(DPMeansTransaction(lam, k_max=k_max), pb=pb,
                    publish=store_publish, **eng_kw)
    eng.partial_fit(x)
    eng.flush()
    return eng


# ------------------------------------------------------------------ router

def test_multi_model_isolation():
    """Publishing to A never changes B's responses; versions independent."""
    x = _stream()
    router = ModelRouter(backend="ref")
    store_a = router.add_model("a")
    store_b = router.add_model("b")
    ea = _train_into(store_a.publish_pass, x[:512], lam=LAM)
    _train_into(store_b.publish_pass, x[256:], lam=2.0)

    rb1 = router.score("b", x[:64])
    # publish a NEW version to A only
    ea.partial_fit(x[512:])
    ea.flush()
    rb2 = router.score("b", x[:64])
    assert rb1.model == rb2.model == "b"
    assert rb2.version == rb1.version            # B's hot-swap untouched
    np.testing.assert_array_equal(rb1.labels, rb2.labels)
    np.testing.assert_array_equal(rb1.scores, rb2.scores)
    ra = router.score("a", x[:64])
    assert ra.model == "a"
    # per-model parity against each model's own snapshot pool
    for nm, resp in (("a", ra), ("b", rb2)):
        snap = router.store(nm).get(resp.version)
        _, ide = nearest_center(snap.as_pool(), x[:64], backend="ref")
        assert np.array_equal(resp.labels, np.asarray(ide))


def test_router_shared_jit_cache_across_tenants():
    """Equal (bucket, capacity) tenants share ONE compilation: serving a
    second model with the same shapes adds zero query-step compiles."""
    x = _stream()
    router = ModelRouter(backend="ref")
    store_a = router.add_model("a")
    store_b = router.add_model("b")
    # Same lam + disjoint-but-similar data → same capacity bucket for both
    _train_into(store_a.publish_pass, x[:512])
    _train_into(store_b.publish_pass, x[:512], lam=LAM * 1.01)
    sa, sb = store_a.latest(), store_b.latest()
    assert sa.capacity == sb.capacity            # test premise
    router.score("a", x[:40])                    # compiles (64-bucket, cap)
    compiles = router.metrics()["query_step_compiles"]
    for _ in range(3):
        router.score("b", x[:40])                # same shapes → warm cache
        router.score("a", x[:40])
    assert router.metrics()["query_step_compiles"] == compiles
    assert router.metrics()["n_models"] == 2


def test_router_unknown_model_and_duplicate():
    router = ModelRouter(backend="ref")
    router.add_model("a")
    with pytest.raises(KeyError):
        router.score("nope", jnp.zeros((4, 8)))
    with pytest.raises(ValueError):
        router.add_model("a")


# --------------------------------------------------------- admission queue

def test_coalesced_vs_solo_bit_parity_per_tagged_version():
    """Concurrent coalesced requests: labels/scores bit-identical to a solo
    service on the SAME tagged version, and the recorded dispatch replays
    bit-exactly through the service's own jitted step."""
    x = _stream()
    store = SnapshotStore(capacity=64)
    _train_into(store.publish_pass, x)
    svc = ClusterService(store, backend="ref", coalesce=True,
                         coalesce_bucket=64, coalesce_delay_ms=25.0,
                         audit_log=True)
    solo = ClusterService(store, backend="ref")

    spans = [(0, 13), (13, 40), (40, 41), (41, 64), (100, 117)]
    results: dict[int, object] = {}

    def client(i, lo, hi):
        results[i] = svc.score(x[lo:hi])

    threads = [threading.Thread(target=client, args=(i, lo, hi))
               for i, (lo, hi) in enumerate(spans)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    for i, (lo, hi) in enumerate(spans):
        resp = results[i]
        ref = solo.score(x[lo:hi])
        assert resp.version == ref.version
        assert np.array_equal(resp.labels, ref.labels)
        # scores: identical algebra on identical rows — here both dispatch
        # shapes are warm jnp paths, and replay below is the bit-exactness
        # contract; solo-vs-coalesced labels are the cross-shape guarantee
        np.testing.assert_allclose(resp.scores, ref.scores, rtol=1e-6)
    # at least some requests actually shared a dispatch
    assert svc.n_groups < len(spans)
    assert svc.n_group_requests == len(spans)

    # bit-exact replay of every recorded dispatch from its tagged version
    for rec in svc.audit:
        snap = store.get(rec.version)
        d2, idx = cs_mod._assign_step(
            snap.centers, snap.mask, np.int32(snap.count),
            jnp.asarray(rec.x), np.int32(rec.n_valid), backend="ref")
        d2, idx = np.asarray(d2), np.asarray(idx)
        for i, (lo, hi) in enumerate(spans):
            resp = results[i]
            if resp.group != rec.group:
                continue
            sl = slice(resp.offset, resp.offset + (hi - lo))
            assert np.array_equal(resp.labels, idx[sl])
            np.testing.assert_array_equal(resp.scores, d2[sl])
    svc.close()


def test_deadline_flush_under_stalled_partner():
    """A lone request (its would-be partner never arrives) is flushed at
    the latency budget, NOT held until the bucket fills."""
    x = _stream()
    store = SnapshotStore()
    _train_into(store.publish_pass, x)
    delay_ms = 30.0
    svc = ClusterService(store, backend="ref", coalesce=True,
                         coalesce_bucket=256, coalesce_delay_ms=delay_ms)
    svc.score(x[:4])                    # warm the jit cache first
    t0 = time.perf_counter()
    resp = svc.score(x[:10])            # 10 rows << 256: can never fill
    dt = time.perf_counter() - t0
    assert resp.labels.shape == (10,)
    assert dt >= delay_ms / 1e3 * 0.5   # it did wait for a partner…
    assert dt < 5.0                     # …but was NOT held indefinitely
    assert svc.n_deadline_flushes >= 1
    assert svc.metrics()["dispatches_per_microbatch"] == 1.0
    svc.close()


def test_coalesce_full_flush_and_oversized_bypass():
    """A request bigger than the coalesce bucket takes the solo path; small
    concurrent ones still coalesce around it."""
    x = _stream()
    store = SnapshotStore()
    _train_into(store.publish_pass, x)
    svc = ClusterService(store, backend="ref", coalesce=True,
                         coalesce_bucket=32, coalesce_delay_ms=20.0,
                         audit_log=True)
    big = svc.score(x[:100])            # > 32 → solo dispatch
    assert big.group == -1 and big.labels.shape == (100,)
    results = []

    def client(lo):
        results.append(svc.score(x[lo:lo + 16]))

    threads = [threading.Thread(target=client, args=(i * 16,))
               for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(r.group >= 0 for r in results)
    svc.close()


def test_coalesced_topk_and_assign_paths():
    x = _stream()
    store = SnapshotStore()
    _train_into(store.publish_pass, x)
    svc = ClusterService(store, backend="ref", coalesce=True,
                         coalesce_bucket=64, coalesce_delay_ms=5.0)
    solo = ClusterService(store, backend="ref")
    k = min(3, store.latest().count)
    rt = svc.topk(x[:20], k=k)
    assert rt.labels.shape == (20, k)
    assert np.array_equal(rt.labels, solo.topk(x[:20], k=k).labels)
    ra = svc.assign(x[:11])
    assert ra.scores is None
    assert np.array_equal(ra.labels, solo.assign(x[:11]).labels)
    svc.close()


# -------------------------------------------------------- delta publication

def _publish_both(eager, delta):
    def publish(res, **kw):
        eager.publish_pass(res, **kw)
        delta.publish_pass(res, **kw)
    return publish


def test_delta_materialize_bit_identical_to_eager_copy():
    x = _stream()
    eager = SnapshotStore(capacity=64)
    delta = SnapshotStore(capacity=64, delta=True)
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64,
                    publish=_publish_both(eager, delta))
    for lo, hi in ((0, 300), (300, 520), (520, 768)):
        eng.partial_fit(x[lo:hi])
    eng.flush()
    assert eager.versions() == delta.versions()
    assert len(eager.versions()) >= 3
    total_rows = delta.delta_rows_published
    assert total_rows == int(eng.pool.count)     # O(ΔK·D): each row once
    for v in eager.versions():
        se, sd = eager.get(v), delta.get(v)
        assert (se.count, se.capacity, se.n_seen, se.epochs) == \
               (sd.count, sd.capacity, sd.n_seen, sd.epochs)
        np.testing.assert_array_equal(np.asarray(se.centers),
                                      np.asarray(sd.centers))
        np.testing.assert_array_equal(np.asarray(se.mask),
                                      np.asarray(sd.mask))


def test_delta_materialize_pool_overflow_epochs():
    """Overflow epochs publish too; delta == eager incl. the overflow flag
    and the full-capacity prefix."""
    x = _stream()
    eager = SnapshotStore()
    delta = SnapshotStore(delta=True)
    eng = OCCEngine(DPMeansTransaction(0.01, k_max=8), pb=64,
                    publish=_publish_both(eager, delta))
    eng.partial_fit(x[:256])
    eng.partial_fit(x[256:512])
    for v in eager.versions():
        se, sd = eager.get(v), delta.get(v)
        assert se.overflow and sd.overflow
        assert se.count == sd.count == 8
        np.testing.assert_array_equal(np.asarray(se.centers),
                                      np.asarray(sd.centers))
    # a service keeps serving from the delta store through overflow
    svc = ClusterService(delta, backend="ref")
    resp = svc.assign(x[:16])
    assert (resp.labels >= 0).all() and (resp.labels < 8).all()


def test_delta_replication_channel_bit_identity():
    """primary → wire → follower: every version reconstructs bit-identically
    and the bytes on the wire are Σ ΔK·D·4, not versions × capacity."""
    x = _stream()
    chan = DeltaChannel()
    primary = SnapshotStore(capacity=64, delta=True, model="m", wire=chan)
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64,
                    publish=primary.publish_pass)
    follower = make_follower(chan, "m", capacity=64)
    for lo, hi in ((0, 300), (300, 768)):
        eng.partial_fit(x[lo:hi])
        chan.pump()                      # interleave delivery with training
    eng.flush()
    chan.pump()
    assert follower.versions() == primary.versions()
    for v in primary.versions():
        sp, sf = primary.get(v), follower.get(v)
        assert (sp.count, sp.capacity) == (sf.count, sf.capacity)
        np.testing.assert_array_equal(np.asarray(sp.centers),
                                      np.asarray(sf.centers))
    assert chan.bytes_sent == int(eng.pool.count) * x.shape[1] * 4
    # a service over the follower is bit-identical to one over the primary
    svp = ClusterService(primary, backend="ref")
    svf = ClusterService(follower, backend="ref")
    rp, rf = svp.score(x[:50]), svf.score(x[:50])
    assert rp.version == rf.version
    np.testing.assert_array_equal(rp.labels, rf.labels)
    np.testing.assert_array_equal(rp.scores, rf.scores)


def test_delta_rebase_on_rewritten_prefix():
    """A publish whose prefix changed (refine-style rewrite) must rebase,
    and the materialized snapshot reflects the NEW prefix."""
    from repro.core.occ import CenterPool
    k_max, d = 16, 4
    c1 = np.zeros((k_max, d), np.float32)
    c1[:3] = np.arange(12, dtype=np.float32).reshape(3, 4)
    pool1 = CenterPool(jnp.asarray(c1), jnp.arange(k_max) < 3,
                       jnp.asarray(3, jnp.int32), jnp.asarray(False))
    store = SnapshotStore(delta=True)
    store.publish_pool(pool1)
    c2 = c1.copy()
    c2[1] += 100.0                       # rewrite an already-published row
    c2[3] = 7.0                          # and append a new one
    pool2 = CenterPool(jnp.asarray(c2), jnp.arange(k_max) < 4,
                       jnp.asarray(4, jnp.int32), jnp.asarray(False))
    store.publish_pool(pool2, verify=True)      # guard detects the rewrite
    snap = store.latest()
    np.testing.assert_array_equal(np.asarray(snap.centers[:4]), c2[:4])
    # the rebase must NOT corrupt older versions: v1 (never materialized
    # before the rebase) still reconstructs its ORIGINAL centers
    v1 = store.get(store.versions()[0])
    np.testing.assert_array_equal(np.asarray(v1.centers[:3]), c1[:3])
    # the one-row guard alone catches a rewrite of the LAST published row
    store2 = SnapshotStore(delta=True)
    store2.publish_pool(pool1)
    c3 = c1.copy()
    c3[2] += 5.0                         # last published row changes
    pool3 = CenterPool(jnp.asarray(c3), jnp.arange(k_max) < 3,
                       jnp.asarray(3, jnp.int32), jnp.asarray(False))
    store2.publish_pool(pool3)           # no verify: O(D) guard must fire
    np.testing.assert_array_equal(
        np.asarray(store2.latest().centers[:3]), c3[:3])


# ------------------------------------------------- warm restore + cap trace

def test_restore_resumes_bit_identical_with_warm_cap():
    x = _stream(1024, seed=3, dim=8)
    store = SnapshotStore(capacity=64)
    eng_a = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64,
                      validate_cap="adaptive", publish=store.publish_pass)
    eng_a.partial_fit(x[:512])
    snap = store.latest()
    assert snap.cap_est is not None          # estimator persisted
    assert snap.cap_trace is not None and len(snap.cap_trace) == 8
    # continue A as the uninterrupted reference
    eng_a.partial_fit(x[512:])
    eng_a.flush()

    # B restores from the snapshot and replays the remaining stream
    eng_b = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64,
                      validate_cap="adaptive")
    eng_b.restore(snap, k_max=128)
    assert eng_b._cap_est == snap.cap_est    # warm, not full-width
    assert eng_b.n_seen == snap.n_seen and eng_b.epochs_done == snap.epochs
    eng_b.partial_fit(x[512:])
    eng_b.flush()
    assert eng_b.cap_history[0] is not None  # first pass ran at a warm cap
    assert int(eng_b.pool.count) == int(eng_a.pool.count)
    np.testing.assert_array_equal(np.asarray(eng_b.pool.centers),
                                  np.asarray(eng_a.pool.centers))

    # restore refuses to clobber a live stream
    with pytest.raises(ValueError):
        eng_a.restore(snap, k_max=128)
    with pytest.raises(ValueError):
        snap.to_pool(k_max=snap.count - 1)


def test_cap_trace_surfaces_in_serving_metrics():
    x = _stream()
    store = SnapshotStore(delta=True)      # metadata flows through deltas too
    _train_into(store.publish_pass, x, validate_cap="adaptive")
    svc = ClusterService(store, backend="ref")
    m = svc.metrics()
    assert m["latest_version"] == store.latest().version
    assert m["cap_trace"] is not None and len(m["cap_trace"]) >= 1
    assert all(isinstance(c, int) for c in m["cap_trace"])
    # non-adaptive engines publish cap traces too (full-width caps) but no
    # estimator
    store2 = SnapshotStore()
    _train_into(store2.publish_pass, x)
    m2 = ClusterService(store2, backend="ref").metrics()
    assert m2["cap_est"] is None and m2["cap_trace"] is not None


# ----------------------------------------------------- §17 typed surface

def test_router_typed_submit_and_shared_config():
    """`router.submit(model, Query)` is bit-identical to the shims; one
    ServeConfig seeds every tenant, per-tenant overrides patch it, and
    the fleet-level metrics expose the QoS aggregates."""
    x = _stream()
    router = ModelRouter(ServeConfig(backend="ref", min_bucket=16))
    store = router.add_model("m")
    _train_into(store.publish_pass, x)
    q = np.asarray(x[:9])
    typed = router.submit("m", Query(q, kind="topk", k=3))
    shim = router.topk("m", q, k=3)
    assert typed.model == shim.model == "m"
    assert typed.version == shim.version and typed.bucket == shim.bucket
    np.testing.assert_array_equal(typed.labels, shim.labels)
    np.testing.assert_array_equal(typed.scores, shim.scores)
    # config propagation: router default -> tenant; overrides patch it
    assert router.service("m").config == router.config
    router.add_model("n", min_bucket=32)
    assert router.service("n").min_bucket == 32
    assert router.service("n").config.backend == "ref"
    m = router.metrics()
    assert m["overload_score"] == 0.0
    assert m["n_shed"] == {"interactive": 0, "batch": 0, "analytics": 0}
    router.close()


def test_router_fleet_shed_signal_crosses_tenants():
    """One tenant's queued backlog sheds ANOTHER tenant's sheddable
    traffic: the shed signal is fleet-wide queue depth, so co-located
    tenants degrade before the shared process melts."""
    x = _stream()
    router = ModelRouter(ServeConfig(
        backend="ref", coalesce=True, coalesce_bucket=64,
        coalesce_delay_ms=20.0, analytics_delay_ms=20_000.0,
        shed_depth=16, audit_log=True))
    sa = router.add_model("a")
    sb = router.add_model("b")
    _train_into(sa.publish_pass, x)
    _train_into(sb.publish_pass, x, lam=6.0)
    # park a backlog past shed_depth on tenant a (analytics, long budget)
    parked = threading.Thread(target=lambda: router.submit(
        "a", Query(x[:32], kind="topk", k=4, priority="analytics",
                   max_staleness=2)))
    parked.start()
    t0 = time.perf_counter()
    while (router.service("a").queue_depth_rows() < 32
           and time.perf_counter() - t0 < 10.0):
        pass
    assert router.service("a").queue_depth_rows() >= 32
    # tenant b's sheddable traffic now degrades off tenant a's backlog...
    rb = router.submit("b", Query(x[:8], priority="batch", max_staleness=1))
    assert rb.degraded and rb.model == "b"
    # ...while b's latest-only traffic is still served fresh
    rb0 = router.submit("b", Query(x[:8]))
    assert not rb0.degraded
    m = router.metrics()
    assert m["n_shed"]["batch"] == 1 and m["overload_score"] >= 1.0
    router.close()
    parked.join(timeout=10)
    assert not parked.is_alive()

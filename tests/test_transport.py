"""Wire protocol + socket transport tests (DESIGN.md §13).

Covers: byte-level golden fixture for every frame type (the format cannot
drift silently), codec round-trips including pool-overflow epochs, the
`Transport` interface across both back ends, socket replication e2e with
acks / commit watermark / snapshot bootstrap, and the `apply_delta`
rebase/verify paths when a follower lags multiple versions behind.

Regenerate the golden fixture (after an INTENTIONAL format change only):
  PYTHONPATH=src python tests/test_transport.py --regen
"""
import os
import struct
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.occ import CenterPool
from repro.distributed import protocol as proto
from repro.distributed.replication import DeltaChannel, make_follower
from repro.distributed.transport import (ReplicationClient, ReplicationServer,
                                         Transport, store_digest)
from repro.serving.snapshot import CenterDelta, SnapshotStore

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "transport_frames.bin")


def _pool(rows: np.ndarray, k_max: int = 16) -> CenterPool:
    rows = np.asarray(rows, np.float32)
    k = rows.shape[0]
    c = jnp.zeros((k_max, rows.shape[1]), jnp.float32).at[:k].set(rows)
    return CenterPool(c, jnp.arange(k_max) < k,
                      jnp.asarray(k, jnp.int32), jnp.asarray(False))


def _golden_frames() -> list[bytes]:
    """Deterministic frame sequence covering EVERY frame type, including a
    snapshot bootstrap, a pool-overflow epoch delta (with a non-finite
    objective), an empty-ΔK delta, and a mixed-dtype proposal block."""
    boot = CenterDelta(
        model="m", version=4, start=0,
        rows=np.linspace(-1.0, 1.0, 20, dtype=np.float32).reshape(5, 4),
        count=5, capacity=8, rebase=True, n_seen=320, epochs=4,
        overflow=False, objective=0.5, cap_est=16, cap_trace=(8, 8, 4, 4))
    tail = CenterDelta(
        model="m", version=5, start=5,
        rows=(np.arange(12, dtype=np.float32).reshape(3, 4) / 8.0),
        count=8, capacity=8, rebase=False, n_seen=384, epochs=5,
        overflow=False, objective=1.25, cap_est=16, cap_trace=None)
    ovf = CenterDelta(
        model="ovf", version=2, start=3, rows=np.zeros((0, 4), np.float32),
        count=3, capacity=8, rebase=False, n_seen=128, epochs=2,
        overflow=True, objective=float("inf"), cap_est=None, cap_trace=(64,))
    return [
        proto.hello_frame("follower", "m", have_version=3, worker=-1,
                          term=2),
        proto.delta_frame(boot, proto.SNAPSHOT, term=2),
        proto.delta_frame(tail),                    # term defaults to 0
        proto.delta_frame(ovf),
        proto.ack_frame("m", 5),
        proto.step_frame(7, 8, term=2),
        proto.propose_frame(7, 1, [np.array([True, False, True]),
                                   np.arange(6, dtype=np.float32).reshape(3, 2),
                                   np.array([2, -1, 0], np.int32)]),
        proto.ctrl_frame("promote", node=1, term=3, watermark=5),
        proto.fin_frame("bye"),
    ]


def _split_frames(buf: bytes) -> list[bytes]:
    out, off = [], 0
    while off < len(buf):
        _, _, _, plen = struct.unpack_from("!4sBBI", buf, off)
        out.append(buf[off:off + 10 + plen])
        off += 10 + plen
    return out


# ------------------------------------------------------------ golden fixture

def test_golden_fixture_bytes_exact():
    """The committed fixture pins the format at the byte level — any codec
    change that alters encoded bytes fails here and must be deliberate."""
    with open(GOLDEN, "rb") as f:
        want = f.read()
    got = b"".join(_golden_frames())
    assert got == want, "wire format drifted from the committed golden bytes"


def test_golden_fixture_covers_every_frame_type():
    with open(GOLDEN, "rb") as f:
        frames = _split_frames(f.read())
    types = {proto.decode_frame(fr)[0] for fr in frames}
    assert types == set(proto.FRAME_NAMES), (
        "golden fixture must exercise every frame type")


def test_golden_fixture_decodes_back():
    with open(GOLDEN, "rb") as f:
        frames = _split_frames(f.read())
    decoded = [proto.decode_frame(fr) for fr in frames]
    assert decoded[0][1] == dict(role="follower", model="m", have_version=3,
                                 worker=-1, term=2)
    boot = proto.frame_delta(decoded[1][1], decoded[1][2])
    assert boot.rebase and boot.start == 0 and boot.count == 5
    assert decoded[1][1]["term"] == 2 and decoded[2][1]["term"] == 0
    ovf = proto.frame_delta(decoded[3][1], decoded[3][2])
    assert ovf.overflow and ovf.rows.shape == (0, 4)
    assert ovf.objective is None      # inf is not JSON-representable
    assert decoded[4][1]["version"] == 5                       # ACK
    assert decoded[5][1] == dict(epoch=7, count=8, term=2)     # STEP
    ep, meta, arrays = decoded[6]                              # PROPOSE
    assert meta["epoch"] == 7 and meta["n_leaves"] == 3
    assert arrays["leaf0"].dtype == np.bool_
    assert arrays["leaf2"].dtype == np.int32
    assert decoded[7][1] == dict(op="promote", node=1, term=3,  # CTRL
                                 watermark=5)
    assert decoded[8][1]["reason"] == "bye"                    # FIN


# ------------------------------------------------------------- codec basics

def test_delta_frame_roundtrip_every_field():
    rng = np.random.default_rng(0)
    d = CenterDelta(model="abc", version=17, start=6,
                    rows=rng.normal(size=(4, 9)).astype(np.float32),
                    count=10, capacity=16, rebase=False, n_seen=1234,
                    epochs=11, overflow=True, objective=-2.5, cap_est=32,
                    cap_trace=(1, 2, 3))
    ftype, meta, arrays = proto.decode_frame(proto.delta_frame(d))
    back = proto.frame_delta(meta, arrays)
    assert ftype == proto.DELTA
    for f in CenterDelta._fields:
        a, b = getattr(d, f), getattr(back, f)
        if f == "rows":
            assert b.dtype == a.dtype and np.array_equal(a, b)
        else:
            assert a == b, f


def test_propose_frame_preserves_dtype_and_shape():
    leaves = [np.array([[True], [False]]),
              np.arange(8, dtype=np.float32).reshape(2, 4),
              np.array([7, -7], np.int32),
              np.arange(2, dtype=np.float64)]
    ftype, meta, arrays = proto.decode_frame(proto.propose_frame(3, 0, leaves))
    assert ftype == proto.PROPOSE and meta["n_leaves"] == 4
    for i, l in enumerate(leaves):
        got = arrays[f"leaf{i}"]
        assert got.dtype == l.dtype and got.shape == l.shape
        assert np.array_equal(got, l)


def test_decode_rejects_garbage():
    frame = proto.fin_frame("x")
    with pytest.raises(ValueError, match="magic"):
        proto.decode_frame(b"NOPE" + frame[4:])
    with pytest.raises(ValueError, match="version"):
        proto.decode_frame(frame[:4] + b"\x63" + frame[5:])
    with pytest.raises(ValueError, match="truncated"):
        proto.decode_frame(frame[:-1])


# -------------------------------------------------------- Transport interface

def test_both_backends_implement_transport():
    chan = DeltaChannel()
    assert isinstance(chan, Transport)
    srv = ReplicationServer()
    try:
        assert isinstance(srv, Transport)
    finally:
        srv.close()


def test_loopback_commit_watermark_tracks_pump():
    chan = DeltaChannel()
    store = SnapshotStore(capacity=8, delta=True, model="m", wire=chan)
    f1 = make_follower(chan, "m", capacity=8)
    assert chan.commit_watermark("m") == 0          # attached, nothing applied
    assert chan.commit_watermark("other") is None   # no followers at all
    for k in (2, 3):
        store.publish_pool(_pool(np.ones((k, 4))))
    assert chan.commit_watermark("m") == 0          # queued, not delivered
    chan.pump()
    assert chan.commit_watermark("m") == 2
    assert f1.versions() == store.versions()


# ------------------------------------------------------- socket replication

def test_socket_replication_acks_watermark_bootstrap():
    """End-to-end over real loopback sockets: in-order delivery with acks,
    commit watermark, late-joiner SNAPSHOT bootstrap, orderly FIN."""
    srv = ReplicationServer()
    store = SnapshotStore(capacity=32, delta=True, model="m", wire=srv)
    c1 = ReplicationClient(srv.address, model="m", capacity=32).start()
    rng = np.random.default_rng(1)
    pools = [_pool(rng.normal(size=(k, 4))) for k in (2, 3, 5, 6, 9)]
    try:
        for p in pools[:3]:
            store.publish_pool(p)
        assert srv.wait_acked(3, "m", timeout=20)
        assert srv.commit_watermark("m") == 3
        # late joiner: must receive a SNAPSHOT (rebase of version 3), then
        # tail versions 4..5 live — landing bit-identical to c1
        c2 = ReplicationClient(srv.address, model="m", capacity=32).start()
        assert c2.wait_version(3)       # bootstrap applied before we move on
        for p in pools[3:]:
            store.publish_pool(p)
        assert srv.wait_acked(5, "m", timeout=20)
        assert c1.wait_version(5) and c2.wait_version(5)
        assert c2.bootstrapped and not c1.bootstrapped
        assert c1.store.versions() == store.versions()
        assert c2.store.versions() == [3, 4, 5]
        assert (store_digest(store) == store_digest(c1.store)
                == store_digest(c2.store))
        for v in (3, 4, 5):     # every shared version, not just the latest
            np.testing.assert_array_equal(
                np.asarray(store.get(v).centers),
                np.asarray(c2.store.get(v).centers))
        m = srv.metrics()
        assert m["n_acks"] >= 8 and m["n_bootstraps"] == 1
        assert m["ack_p99_ms"] >= m["ack_p50_ms"] >= 0.0
    finally:
        srv.close()
    c1.join(10)
    c2.join(10)
    assert c1.fin_reason == "shutdown"


def test_socket_reconnect_at_head_tails_without_bootstrap():
    """A follower reconnecting with have_version == latest just tails."""
    srv = ReplicationServer()
    store = SnapshotStore(capacity=8, delta=True, model="m", wire=srv)
    try:
        store.publish_pool(_pool(np.ones((2, 4))))
        c1 = ReplicationClient(srv.address, model="m", capacity=8).start()
        assert c1.wait_version(1)
        c1.close()      # drop the link, keep the store
        c1.join(10)
        c2 = ReplicationClient(srv.address, model="m",
                               store=c1.store).start()
        deadline = time.monotonic() + 10
        while srv.followers("m") < 1:   # registered before the next publish
            assert time.monotonic() < deadline
            time.sleep(0.01)
        store.publish_pool(_pool(np.ones((4, 4)) * 2))
        assert c2.wait_version(2)
        assert not c2.bootstrapped          # was at head: pure tail
        assert c2.store.versions() == [1, 2]
    finally:
        srv.close()


def test_socket_stale_reconnect_bootstraps_over_existing_store():
    """A follower that fell multiple versions behind is resynced by a
    rebase SNAPSHOT applied over its EXISTING store (apply_delta rebase
    semantics — no special resync path)."""
    srv = ReplicationServer()
    store = SnapshotStore(capacity=8, delta=True, model="m", wire=srv)
    rng = np.random.default_rng(2)
    try:
        store.publish_pool(_pool(rng.normal(size=(2, 4))))
        c1 = ReplicationClient(srv.address, model="m", capacity=8).start()
        assert c1.wait_version(1)
        c1.close()
        c1.join(10)
        for k in (3, 5, 8):                 # follower misses three versions
            store.publish_pool(_pool(rng.normal(size=(k, 4))))
        c2 = ReplicationClient(srv.address, model="m",
                               store=c1.store).start()
        assert c2.wait_version(4)
        assert c2.bootstrapped
        assert store_digest(c2.store) == store_digest(store)
    finally:
        srv.close()


def test_server_local_attach_is_loopback_follower():
    srv = ReplicationServer()
    store = SnapshotStore(capacity=8, delta=True, model="m", wire=srv)
    try:
        store.publish_pool(_pool(np.ones((2, 4))))
        late = SnapshotStore(capacity=8, delta=True, model="m")
        srv.attach("m", late)               # attach AFTER a publish
        store.publish_pool(_pool(np.ones((3, 4)) * 3))
        assert late.versions() == [1, 2]    # bootstrapped + tailed, sync
        assert srv.commit_watermark("m") == 2
        assert store_digest(late) == store_digest(store)
    finally:
        srv.close()


# ----------------------- apply_delta under a lagging watermark (satellite)

def _publish_seq(store, rng, ks):
    """Publish a prefix-preserving (genuinely append-only) version chain."""
    base = rng.normal(size=(max(ks), 4)).astype(np.float32)
    for k in ks:
        store.publish_pool(_pool(base[:k]))
    return base


def test_apply_delta_backlog_multiple_versions_behind():
    """A follower draining a 5-version backlog in one pump reproduces every
    version — the watermark advances through each delta in order."""
    chan = DeltaChannel()
    primary = SnapshotStore(capacity=8, delta=True, model="m", wire=chan)
    follower = make_follower(chan, "m", capacity=8)
    rng = np.random.default_rng(3)
    _publish_seq(primary, rng, (1, 2, 4, 5, 9))
    assert chan.pending() == 5 and follower.n_deltas == 0
    assert chan.commit_watermark("m") == 0          # maximally lagged
    chan.pump()
    assert chan.commit_watermark("m") == 5
    assert follower.versions() == primary.versions()
    for v in primary.versions():
        np.testing.assert_array_equal(
            np.asarray(primary.get(v).centers),
            np.asarray(follower.get(v).centers))


def test_apply_delta_rebase_mid_backlog():
    """A rebase inside the backlog (count shrank — e.g. a refine between
    passes) re-logs the prefix; the lagging follower replays append →
    rebase → append and lands bit-identical, with its OLD versions still
    materializing from the pre-rebase log."""
    chan = DeltaChannel()
    primary = SnapshotStore(capacity=8, delta=True, model="m", wire=chan)
    follower = make_follower(chan, "m", capacity=8)
    rng = np.random.default_rng(4)
    _publish_seq(primary, rng, (3, 6))
    shrunk = rng.normal(size=(2, 4)).astype(np.float32)
    primary.publish_pool(_pool(shrunk))             # count 6 → 2: forces rebase
    grown = np.concatenate(
        [shrunk, rng.normal(size=(2, 4)).astype(np.float32)])
    primary.publish_pool(_pool(grown))              # genuine append again
    chan.pump()                                     # drain all four at once
    assert follower.versions() == primary.versions() == [1, 2, 3, 4]
    for v in (1, 2, 3, 4):                          # incl. pre-rebase versions
        np.testing.assert_array_equal(
            np.asarray(primary.get(v).centers),
            np.asarray(follower.get(v).centers))


def test_apply_delta_gap_detected():
    """A skipped delta must raise, not corrupt: the follower's watermark
    check catches out-of-order/lossy delivery."""
    primary = SnapshotStore(capacity=8, delta=True, model="m")
    rng = np.random.default_rng(5)
    deltas = []
    primary.wire = type("W", (), {"send": lambda self, d: deltas.append(d)})()
    _publish_seq(primary, rng, (2, 4, 7))
    follower = SnapshotStore(capacity=8, delta=True, model="m")
    follower.apply_delta(deltas[0])
    with pytest.raises(ValueError, match="delta gap"):
        follower.apply_delta(deltas[2])             # skipped version 2
    follower.apply_delta(deltas[1])                 # in order: fine
    follower.apply_delta(deltas[2])
    assert follower.versions() == [1, 2, 3]


def test_publish_verify_catches_deep_prefix_rewrite():
    """The O(D) one-row guard only probes the LAST published row; verify=
    True upgrades to the full bit-check.  A rewrite deeper in the prefix
    slips past the fast guard (documented tradeoff) but must force a
    rebase under verify=True — and the rebase delta resyncs a follower
    that had already applied the pre-rewrite versions."""
    chan = DeltaChannel()
    primary = SnapshotStore(capacity=8, delta=True, model="m", wire=chan)
    follower = make_follower(chan, "m", capacity=8)
    rows = np.arange(12, dtype=np.float32).reshape(3, 4)
    primary.publish_pool(_pool(rows))
    chan.pump()
    rewritten = rows.copy()
    rewritten[0] += 100.0                           # NOT the last row
    grown = np.concatenate([rewritten, np.ones((1, 4), np.float32)])
    snap_fast = primary.publish_pool(_pool(grown))  # fast guard misses it
    assert not np.array_equal(np.asarray(snap_fast.materialize().centers[0]),
                              rewritten[0])         # stale row 0: the hazard
    snap = primary.publish_pool(_pool(grown), verify=True)
    d = snap.materialize()
    np.testing.assert_array_equal(np.asarray(d.centers[:4]), grown)
    chan.pump()                                     # follower gets the rebase
    assert store_digest(follower) == store_digest(primary)


# ------------------------- backpressure, reconnect, fencing (§14 transport)

def test_ctrl_frame_roundtrip_and_positional_op():
    ftype, meta, _ = proto.decode_frame(
        proto.ctrl_frame("orphaned", node=2, watermark=7))
    assert ftype == proto.CTRL
    assert meta == dict(op="orphaned", node=2, watermark=7)
    with pytest.raises(TypeError):       # op is positional-only in spirit
        proto.ctrl_frame("x", op="y")


def test_slow_follower_bounded_queue_snapshot_resync():
    """Backpressure (§14): a WAN-slow link (the server writer is rate-
    limited, so frames back up in the per-follower queue) must not grow
    server memory — the queue stays bounded, overflow drops the backlog
    to ONE SNAPSHOT, and the follower still converges bit-identically."""
    from repro.distributed.fault import FaultPlan, FaultRule
    plan = FaultPlan([FaultRule("server.writer", "delay", every=1,
                                delay_s=0.05)])
    srv = ReplicationServer(max_queue=4, fault=plan)
    store = SnapshotStore(capacity=64, delta=True, model="m", wire=srv)
    rng = np.random.default_rng(6)
    base = rng.normal(size=(40, 4)).astype(np.float32)
    try:
        c = ReplicationClient(srv.address, model="m", capacity=64)
        c.start()
        deadline = time.monotonic() + 10
        while srv.followers("m") < 1:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        bound = srv.max_pending_bound()
        for k in range(1, 41):          # 40 versions into a throttled pipe
            store.publish_pool(_pool(base[:k], k_max=64))
            assert srv.pending() <= bound, "server queue memory unbounded"
        assert srv.wait_acked(40, "m", timeout=20)
        m = srv.metrics()
        assert m["n_resyncs"] >= 1 and m["n_dropped_frames"] > 0
        assert store_digest(c.store) == store_digest(store)
        # versions lost to backpressure were rebased away, not corrupted
        assert c.store.latest_meta().version == 40
    finally:
        srv.close()
    c.join(10)


def test_client_reconnects_with_backoff_after_stream_break():
    """Kill the follower's socket server-side mid-stream: the client must
    reconnect (with recorded jittered backoff), resume from its last
    applied version, and land bit-identical."""
    srv = ReplicationServer()
    store = SnapshotStore(capacity=16, delta=True, model="m", wire=srv)
    rng = np.random.default_rng(7)
    base = rng.normal(size=(8, 4)).astype(np.float32)
    try:
        store.publish_pool(_pool(base[:2]))
        c = ReplicationClient(srv.address, model="m", capacity=16,
                              reconnect=True, backoff_s=0.01, seed=1)
        c.start()
        assert c.wait_version(1)
        # hard-reset the server side of the link (no FIN)
        with srv._lock:
            conn = srv._conns[0]
        conn.sock.shutdown(1)           # SHUT_WR: client sees EOF
        deadline = time.monotonic() + 10
        while c.n_reconnects < 1:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        while srv.followers("m") < 1:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        for k in (4, 6):
            store.publish_pool(_pool(base[:k]))
        assert c.wait_version(3)
        assert c.n_reconnects >= 1 and len(c.backoff_log) >= 1
        assert all(d > 0 for d in c.backoff_log)
        assert store_digest(c.store) == store_digest(store)
    finally:
        srv.close()
    c.join(10)


def test_dropped_frame_heals_by_reconnect_resync():
    """Chaos `drop` on the server writer loses one live delta; the client
    detects the sequence gap, reconnects, and the server's bootstrap path
    resyncs it — zero corruption, bit-identical final state."""
    from repro.distributed.fault import FaultPlan, FaultRule
    plan = FaultPlan([FaultRule("server.writer", "drop", nth=2)])
    srv = ReplicationServer(fault=plan)
    store = SnapshotStore(capacity=16, delta=True, model="m", wire=srv)
    rng = np.random.default_rng(8)
    base = rng.normal(size=(8, 4)).astype(np.float32)
    try:
        c = ReplicationClient(srv.address, model="m", capacity=16,
                              reconnect=True, backoff_s=0.01, seed=2)
        c.start()
        deadline = time.monotonic() + 10
        while srv.followers("m") < 1:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        for k in (2, 4, 6):             # frame 2 (version 2) is dropped
            store.publish_pool(_pool(base[:k]))
        assert c.wait_version(3, timeout=20)
        assert c.n_gaps >= 1
        assert c.bootstrapped           # healed via SNAPSHOT resync
        assert store_digest(c.store) == store_digest(store)
        assert len(plan.events) >= 1 and plan.events[0].kind == "drop"
    finally:
        srv.close()
    c.join(10)


def test_duplicated_frame_acked_not_reapplied():
    from repro.distributed.fault import FaultPlan, FaultRule
    plan = FaultPlan([FaultRule("server.writer", "dup", nth=2)])
    srv = ReplicationServer(fault=plan)
    store = SnapshotStore(capacity=16, delta=True, model="m", wire=srv)
    rng = np.random.default_rng(9)
    base = rng.normal(size=(6, 4)).astype(np.float32)
    try:
        c = ReplicationClient(srv.address, model="m", capacity=16)
        c.start()
        deadline = time.monotonic() + 10
        while srv.followers("m") < 1:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        for k in (2, 4, 6):
            store.publish_pool(_pool(base[:k]))
        assert c.wait_version(3, timeout=20)
        assert srv.wait_acked(3, "m", timeout=20)
        assert c.n_duplicates == 1      # redelivery ACKed, applied once
        assert c.store.versions() == [1, 2, 3]
        assert store_digest(c.store) == store_digest(store)
    finally:
        srv.close()
    c.join(10)


def test_zombie_master_fenced_by_newer_term_hello():
    """§14 fencing, server side: a HELLO carrying a newer term marks the
    server fenced; its next publish raises instead of corrupting
    followers of the new master."""
    srv = ReplicationServer(term=1)
    store = SnapshotStore(capacity=8, delta=True, model="m", wire=srv)
    try:
        store.publish_pool(_pool(np.ones((2, 4))))
        c = ReplicationClient(srv.address, model="m", term=3)
        c.connect()
        c.run()                         # server FINs us immediately
        assert c.fin_reason is not None and "fenced" in c.fin_reason
        deadline = time.monotonic() + 10
        while not srv.fenced:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        with pytest.raises(RuntimeError, match="fenced"):
            store.publish_pool(_pool(np.ones((3, 4))))
        assert srv.metrics()["n_fenced_hellos"] == 1
    finally:
        srv.close()


def test_client_rejects_stale_term_frames():
    """§14 fencing, client side: frames stamped with an OLDER term than
    the client has seen are discarded without ACK."""
    srv = ReplicationServer(term=0)      # the zombie: still at term 0
    store = SnapshotStore(capacity=8, delta=True, model="m", wire=srv)
    try:
        c = ReplicationClient(srv.address, model="m", term=2)
        # client term 2 > server term 0: server accepts (peer_term > term
        # only fences when the PEER is newer — here the client is newer,
        # which fences the server; so use a fresh un-fenced server below)
        srv.term = 2                     # handshake passes at equal term
        c.start()
        deadline = time.monotonic() + 10
        while srv.followers("m") < 1:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        srv.term = 1                     # demote AFTER handshake: zombie
        store.publish_pool(_pool(np.ones((2, 4))))
        deadline = time.monotonic() + 10
        while c.n_fenced < 1:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        assert c.store.latest_meta() is None     # nothing applied
        assert c.n_applied == 0
    finally:
        srv.close()


def test_wait_acked_wakes_on_follower_drop():
    """Satellite: a caller blocked in wait_acked must wake promptly when
    the lagging follower is dropped — not run out the full timeout."""
    from repro.distributed.fault import FaultPlan, FaultRule
    # follower stalls forever on its first apply: never acks version 1
    plan = FaultPlan([FaultRule("client.apply", "delay", nth=1,
                                delay_s=60.0)])
    srv = ReplicationServer()
    store = SnapshotStore(capacity=8, delta=True, model="m", wire=srv)
    try:
        c = ReplicationClient(srv.address, model="m", fault=plan)
        c.start()
        deadline = time.monotonic() + 10
        while srv.followers("m") < 1:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        store.publish_pool(_pool(np.ones((2, 4))))
        result = {}

        def waiter():
            t0 = time.monotonic()
            ok = srv.wait_acked(1, "m", timeout=30.0)
            result.update(ok=ok, took=time.monotonic() - t0)

        import threading
        t = threading.Thread(target=waiter, daemon=True)
        t.start()
        time.sleep(0.3)                  # waiter is blocked on the ack
        with srv._lock:
            conn = srv._conns[0]
        srv._drop(conn)                  # follower dies mid-wait
        t.join(10)
        assert result, "wait_acked never returned"
        # zero live followers: barrier is vacuous over the survivors
        assert result["ok"] is True
        assert result["took"] < 5.0, "waiter ran toward the full timeout"
    finally:
        c.stop()
        srv.close()


def test_wait_acked_wakes_on_close():
    """Satellite: closing the server mid-wait returns False promptly."""
    from repro.distributed.fault import FaultPlan, FaultRule
    plan = FaultPlan([FaultRule("client.apply", "delay", nth=1,
                                delay_s=60.0)])
    srv = ReplicationServer()
    store = SnapshotStore(capacity=8, delta=True, model="m", wire=srv)
    c = ReplicationClient(srv.address, model="m", fault=plan)
    c.start()
    deadline = time.monotonic() + 10
    while srv.followers("m") < 1:
        assert time.monotonic() < deadline
        time.sleep(0.01)
    store.publish_pool(_pool(np.ones((2, 4))))
    result = {}

    def waiter():
        t0 = time.monotonic()
        ok = srv.wait_acked(1, "m", timeout=30.0)
        result.update(ok=ok, took=time.monotonic() - t0)

    import threading
    t = threading.Thread(target=waiter, daemon=True)
    t.start()
    time.sleep(0.3)
    srv.close()
    t.join(10)
    assert result, "wait_acked never returned"
    assert result["ok"] is False         # barrier abandoned, not vacuous
    assert result["took"] < 5.0


def test_server_abort_sends_no_fin():
    """abort() is the crash path: followers see bare EOF (the orphaned
    signal), never an orderly FIN."""
    srv = ReplicationServer()
    store = SnapshotStore(capacity=8, delta=True, model="m", wire=srv)
    c = ReplicationClient(srv.address, model="m")
    c.start()
    deadline = time.monotonic() + 10
    while srv.followers("m") < 1:
        assert time.monotonic() < deadline
        time.sleep(0.01)
    store.publish_pool(_pool(np.ones((2, 4))))
    assert srv.wait_acked(1, "m", timeout=20)
    srv.abort()
    c.join(10)
    assert c.fin_reason is None          # EOF, not FIN
    assert c.store.latest_meta().version == 1


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "wb") as f:
            f.write(b"".join(_golden_frames()))
        print(f"wrote {GOLDEN}")


def test_chaotic_stream_converges_for_any_seed(inject_seed):
    """Probabilistic chaos sweep (the CI chaos job re-runs this under
    several ``--inject-seed`` values): random drops, duplicates and
    delays on the server writer, a reconnecting client — for ANY seed the
    follower must converge to the primary's exact store.  The gap/resync
    and duplicate-suppression machinery is what's under test; the seed
    only decides which frames get hit."""
    from repro.distributed.fault import FaultPlan, FaultRule
    plan = FaultPlan([FaultRule("server.writer", "drop", prob=0.25),
                      FaultRule("server.writer", "dup", prob=0.25),
                      FaultRule("server.writer", "delay", prob=0.25,
                                delay_s=0.002)],
                     seed=inject_seed)
    srv = ReplicationServer(fault=plan)
    store = SnapshotStore(capacity=64, delta=True, model="m", wire=srv)
    rng = np.random.default_rng(10)
    base = rng.normal(size=(48, 4)).astype(np.float32)
    try:
        c = ReplicationClient(srv.address, model="m", capacity=64,
                              reconnect=True, max_retries=100,
                              backoff_s=0.01, seed=inject_seed)
        c.start()
        deadline = time.monotonic() + 10
        while srv.followers("m") < 1:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        for k in range(1, 9):
            store.publish_pool(_pool(base[:k], k_max=64))
        # a DROPPED tail frame is only detectable when a later frame
        # arrives — nudge with fresh versions until the follower caught up
        # (each nudge is a real publish, so convergence stays bit-exact)
        k = 9
        while not c.wait_version(store.latest_meta().version, timeout=1.0):
            assert k < 48, "follower failed to converge under chaos"
            store.publish_pool(_pool(base[:k], k_max=64))
            k += 1
        assert store_digest(c.store) == store_digest(store)
        assert c.store.latest_meta().count == store.latest_meta().count
    finally:
        srv.close()
    c.join(10)

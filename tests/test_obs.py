"""Unified telemetry tests (DESIGN.md §15).

Covers: registry semantics (labeled families, kind pinning, exact-then-
bucketed percentiles), lost-update-free concurrent counting (the race the
old ad-hoc `metrics()` dicts had between the admission-queue flusher and
request threads), a byte-level golden Perfetto trace fixture (same pattern
as the transport wire-format fixture), the `validate_trace` schema check
(nesting + per-track monotone timestamps), cross-process trace merging,
the coordinator CTRL metrics endpoint, and — slow — a full chaos HA run
with ``--trace-out`` whose merged timeline must be valid Perfetto JSON
carrying spans from >= 4 subsystems.

Regenerate the golden fixture (after an INTENTIONAL format change only):
  PYTHONPATH=src python tests/test_obs.py --regen
"""
import json
import math
import os
import threading

import numpy as np
import pytest

from repro.obs import Obs, Tracer, load_trace, merge_traces, trace_categories, \
    validate_trace
from repro.obs.metrics import DEFAULT_BUCKETS, Histogram, MetricsRegistry

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "trace_events.json")


# ------------------------------------------------------------------ registry

def test_counter_gauge_basics():
    m = MetricsRegistry()
    m.counter("c").inc()
    m.counter("c").inc(2.5)
    assert m.value("c") == 3.5
    m.gauge("g").set(7)
    m.gauge("g").add(-2)
    assert m.value("g") == 5.0
    assert m.value("never_touched") == 0.0


def test_labeled_families_are_independent():
    m = MetricsRegistry()
    m.counter("bytes", dir="in").inc(10)
    m.counter("bytes", dir="out").inc(1)
    assert m.value("bytes", dir="in") == 10
    assert m.value("bytes", dir="out") == 1
    # label order does not matter
    m.counter("x", a=1, b=2).inc()
    assert m.value("x", b=2, a=1) == 1


def test_kind_is_pinned_at_first_use():
    m = MetricsRegistry()
    m.counter("n")
    with pytest.raises(TypeError):
        m.gauge("n")
    with pytest.raises(TypeError):
        m.histogram("n")


def test_timer_observes_elapsed_seconds():
    m = MetricsRegistry()
    with m.timer("t_s"):
        pass
    h = m.get_histogram("t_s")
    assert h.count == 1
    assert 0.0 <= h.min < 1.0


def test_histogram_exact_percentiles_match_numpy():
    rng = np.random.default_rng(0)
    xs = rng.exponential(scale=1e-3, size=500)
    h = Histogram()
    for v in xs:
        h.observe(v)
    for q in (0, 25, 50, 90, 99, 100):
        assert h.percentile(q) == pytest.approx(np.percentile(xs, q),
                                                rel=1e-9)
    assert h.min == xs.min() and h.max == xs.max()
    assert h.mean == pytest.approx(xs.mean())


def test_histogram_bucket_fallback_is_bounded():
    rng = np.random.default_rng(1)
    xs = rng.exponential(scale=1e-3, size=2000)
    h = Histogram(sample_limit=100)      # force the bucketed path
    for v in xs:
        h.observe(v)
    p99 = h.percentile(99)
    exact = np.percentile(xs, 99)
    # bucket-interpolated: within one geometric x4 bucket of exact (the
    # estimate may exceed the sample max — the bucket's upper bound does)
    assert exact / 4.5 <= p99 <= exact * 4.5
    assert math.isnan(Histogram().percentile(50))


def test_dump_and_exposition():
    m = MetricsRegistry()
    m.counter("reqs", model="a").inc(3)
    m.gauge("depth").set(2)
    m.histogram("lat_s").observe(0.5)
    d = m.dump()
    assert d["reqs"]["type"] == "counter"
    assert d["reqs"]["values"]['model="a"'] == 3
    assert d["lat_s"]["values"][""]["count"] == 1
    json.dumps(d)                         # JSON-safe
    text = m.exposition()
    assert '# TYPE reqs counter' in text
    assert 'reqs{model="a"} 3' in text
    assert "lat_s_count 1" in text and "lat_s_p99" in text


def test_concurrent_increments_lose_nothing():
    """The §15 motivation: the flusher-vs-request-thread read-modify-write
    race the old dict counters had must be structurally impossible."""
    m = MetricsRegistry()
    threads, per = 8, 5000

    def hammer(i):
        c_shared = m.counter("shared")
        for _ in range(per):
            c_shared.inc()
            m.counter("labeled", worker=i % 2).inc(2)
            m.gauge("depth").add(1)
            m.histogram("h_s").observe(1e-4)

    ts = [threading.Thread(target=hammer, args=(i,)) for i in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert m.value("shared") == threads * per
    assert (m.value("labeled", worker=0) + m.value("labeled", worker=1)
            == 2 * threads * per)
    assert m.value("depth") == threads * per
    assert m.get_histogram("h_s").count == threads * per


# ------------------------------------------------------------ golden fixture

def _golden_tracer() -> Tracer:
    """Deterministic event stream: injectable clock (1ms per reading),
    pinned pid/tids — byte-stable across machines and runs."""
    state = {"t": 0.0}

    def clock():
        state["t"] += 1e-3
        return state["t"]

    tr = Tracer(process_name="golden", pid=7, clock=clock)
    tr.set_thread_name("main", tid=1)
    with tr.span("engine.pass", cat="engine", args={"epochs": 2}, tid=1):
        with tr.span("engine.validate", cat="engine", tid=1) as sp:
            sp.set(accepted=3)
        tr.instant("fault.inject", cat="fault",
                   args={"point": "master.commit", "kind": "kill"}, tid=1)
    tr.counter("transport.queue_depth", {"f0": 2, "f1": 0},
               cat="transport", tid=1)
    tr.complete("engine.epoch", ts_us=250.0, dur_us=125.0, cat="engine",
                args={"epoch": 0, "synthetic_timing": True}, tid=2)
    tr.complete("wal.append", ts_us=9000.0, dur_us=40.0, cat="wal",
                args={"version": 3}, tid=2)
    return tr


def test_trace_golden_bytes_exact():
    """The committed fixture pins the export format at the byte level —
    a field rename or serialization change fails here and must be
    deliberate (Perfetto/catapult consume these files)."""
    with open(GOLDEN, "rb") as f:
        want = f.read()
    assert _golden_tracer().json_bytes() == want, (
        "trace export drifted from the committed golden bytes")


def test_trace_golden_schema():
    trace = json.loads(_golden_tracer().json_bytes())
    assert validate_trace(trace) == []
    assert trace_categories(trace) == {"engine", "fault", "transport", "wal"}
    assert trace["displayTimeUnit"] == "ms"
    phs = {ev["ph"] for ev in trace["traceEvents"]}
    assert phs == {"M", "X", "i", "C"}
    # nested span closed before its parent; args survived
    spans = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    outer = next(e for e in spans if e["name"] == "engine.pass")
    inner = next(e for e in spans if e["name"] == "engine.validate")
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert inner["args"]["accepted"] == 3


# ----------------------------------------------------------- trace semantics

def test_span_records_exception_and_reraises():
    tr = Tracer(pid=1, clock=iter(np.arange(1, 10) * 1e-3).__next__)
    with pytest.raises(ValueError):
        with tr.span("boom", cat="t", tid=1):
            raise ValueError("x")
    ev = [e for e in tr.events() if e["ph"] == "X"][0]
    assert ev["args"]["error"] == "ValueError"
    assert ev["dur"] >= 0


def test_validate_trace_rejects_bad_traces():
    assert validate_trace({}) == ["traceEvents missing or not a list"]
    bad = {"traceEvents": [dict(name="a", ph="X", ts=0, pid=1, tid=1)]}
    assert any("missing dur" in p for p in validate_trace(bad))
    overlap = {"traceEvents": [
        dict(name="a", ph="X", ts=0.0, dur=10.0, pid=1, tid=1),
        dict(name="b", ph="X", ts=5.0, dur=10.0, pid=1, tid=1),
    ]}
    assert any("does not nest" in p for p in validate_trace(overlap))
    # same interval on DIFFERENT tracks is fine
    ok = {"traceEvents": [
        dict(name="a", ph="X", ts=0.0, dur=10.0, pid=1, tid=1),
        dict(name="b", ph="X", ts=5.0, dur=10.0, pid=1, tid=2),
    ]}
    assert validate_trace(ok) == []


def test_point_events_emitted_in_timestamp_order():
    """instant/counter events are stamped at call time, so within one
    tracer their list order must already be their timeline order (spans
    are stamped at exit and are ordered by `validate_trace` instead)."""
    tr = _golden_tracer()
    pts = [ev["ts"] for ev in tr.events() if ev["ph"] in ("i", "C")]
    assert pts and pts == sorted(pts)


def test_merge_traces_combines_processes_and_skips_torn(tmp_path):
    a = Tracer(process_name="p0", pid=1,
               clock=iter(np.arange(1, 50) * 1e-3).__next__)
    with a.span("x", cat="engine", tid=1):
        pass
    p0 = str(tmp_path / "p0.json")
    a.save(p0)
    b = Tracer(process_name="p1", pid=2,
               clock=iter(np.arange(1, 50) * 1e-3).__next__)
    b.instant("y", cat="ha", tid=1)
    torn = str(tmp_path / "torn.json")
    with open(torn, "w") as f:
        f.write('{"traceEvents": [')    # crashed writer: not valid JSON
    out = str(tmp_path / "merged.json")
    merged = merge_traces(out, p0, b, torn)
    assert {e["pid"] for e in merged["traceEvents"]} == {1, 2}
    assert validate_trace(merged) == []
    assert load_trace(out) == merged


def test_obs_bundle_noop_without_tracer(tmp_path):
    obs = Obs()
    with obs.span("a", cat="x"):        # must not raise, must not record
        pass
    obs.instant("b")
    obs.flush()                         # no trace_path: no-op
    path = str(tmp_path / "t.json")
    obs2 = Obs(tracer=Tracer(process_name="p", pid=3), trace_path=path)
    with obs2.span("a", cat="x", epoch=1):
        pass
    obs2.flush()
    t = load_trace(path)
    assert any(e["name"] == "a" for e in t["traceEvents"])


# ------------------------------------------------- coordinator CTRL endpoint

def test_coordinator_metrics_endpoint():
    """CTRL op "metrics" returns the driver registry in text exposition
    form over one ephemeral connection."""
    import socket
    from repro.launch.ha_cluster import HAConfig, _Coordinator, _read_ctrl, \
        _send_ctrl

    obs = Obs()
    obs.metrics.counter("ha_promotions").inc(2)
    obs.metrics.histogram("engine_pass_s").observe(0.1)
    coord = _Coordinator(HAConfig(), obs=obs)
    try:
        s = socket.create_connection(("127.0.0.1", coord.port), timeout=10.0)
        _send_ctrl(s, "metrics")
        reply = _read_ctrl(s)
        s.close()
        assert reply["op"] == "metrics"
        assert "ha_promotions 2" in reply["text"]
        assert "engine_pass_s_count 1" in reply["text"]
    finally:
        coord.close()


# ------------------------------------------------------- chaos run e2e trace

@pytest.mark.slow
def test_ha_chaos_run_emits_multisubsystem_trace(tmp_path):
    """The §15 acceptance: one kill-and-promote HA run with --trace-out
    yields ONE valid Perfetto timeline with spans from engine, transport,
    WAL and the fault/HA control plane — including events from the KILLED
    master (FaultPlan flushes its trace before os._exit)."""
    from repro.launch.ha_cluster import HAConfig, run_ha_cluster

    out = str(tmp_path / "trace.json")
    rec = run_ha_cluster(HAConfig(
        n=1024, dim=8, pb=64, k_max=128, lam=3.0, n_workers=2, n_nodes=3,
        kill_master_after_version=6, trace_out=out, quiet=True))
    assert rec["promotions"] == 1
    trace = load_trace(out)
    assert validate_trace(trace) == []
    cats = trace_categories(trace)
    assert {"engine", "transport", "wal", "fault"} <= cats, cats
    assert "ha" in cats
    names = {e["name"] for e in trace["traceEvents"]}
    assert "engine.epoch" in names       # per-epoch spans
    assert "wal.append" in names         # durability plane
    assert "fault.inject" in names       # the chaos kill itself
    assert "ha.promote" in names         # the promotion decision
    # the killed master's pid is present (trace survived os._exit)
    pids = {e["pid"] for e in trace["traceEvents"]}
    assert len(pids) >= 4                # driver + 3 nodes


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "wb") as f:
            f.write(_golden_tracer().json_bytes())
        print(f"regenerated {GOLDEN}")

"""Roofline machinery: HLO collective parsing, terms, analytic-model
validation against XLA cost_analysis on unrolled configs."""
import jax
import jax.numpy as jnp
import pytest

from repro import roofline
from repro.configs import ARCHS, SHAPES
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.models.transformer import segments_for

FAKE_HLO = """
HloModule test

%body.1 (p: (f32[8,16])) -> (f32[8,16]) {
  %ar = f32[8,16]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = (f32[8,16]) tuple(%ar)
}

%cond.1 (p: (f32[8,16])) -> pred[] {
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[64,32]) -> f32[64,32] {
  %ag = f32[64,32]{1,0} all-gather(%a), dimensions={0}
  %w = (f32[8,16]) while(%init), condition=%cond.1, body=%body.1
  %cp = f32[4,4]{1,0} collective-permute(%b), source_target_pairs={{0,1}}
  ROOT %r = f32[64,32]{1,0} add(%ag, %ag)
}
"""


def test_parse_collectives_basic():
    st = roofline.parse_collectives(FAKE_HLO, loop_multiplier=1)
    assert st.bytes_by_kind["all-gather"] == 64 * 32 * 4
    assert st.bytes_by_kind["all-reduce"] == 8 * 16 * 4
    assert st.bytes_by_kind["collective-permute"] == 4 * 4 * 4


def test_parse_collectives_loop_scaling():
    st = roofline.parse_collectives(FAKE_HLO, loop_multiplier=10)
    # only the all-reduce lives in the while body
    assert st.bytes_by_kind["all-reduce"] == 10 * 8 * 16 * 4
    assert st.bytes_by_kind["all-gather"] == 64 * 32 * 4


def test_roofline_terms_dominance():
    t = roofline.roofline_terms(197e12, 100e9, 1e9)   # 1s compute
    assert t["dominant"] == "compute_s"
    t = roofline.roofline_terms(1e9, 819e9 * 2, 0)
    assert t["dominant"] == "memory_s"


def test_model_flops_conventions():
    shape_t = SHAPES["train_4k"]
    shape_d = SHAPES["decode_32k"]
    assert roofline.model_flops(None, shape_t, 10) == 6 * 10 * 256 * 4096
    assert roofline.model_flops(None, shape_d, 10) == 2 * 10 * 128


@pytest.mark.parametrize("name,kw", [
    ("granite-3-2b", dict(n_layers=2, d_model=512, n_heads=8, n_kv_heads=4,
                          head_dim=64, d_ff=1024, vocab=4096)),
    ("olmoe-1b-7b", dict(n_layers=2, d_model=512, n_heads=8, n_kv_heads=8,
                         head_dim=64, d_ff=256, vocab=4096)),
    ("xlstm-1.3b", dict(n_layers=4, d_model=512, n_heads=2, head_dim=256,
                        vocab=4096, slstm_every=2)),
])
def test_analytic_flops_vs_hlo(name, kw):
    """The analytic model (what the roofline uses) matches XLA's own count
    on fully-unrolled configs within 25% (HLO also counts transcendentals)."""
    cfg = ARCHS[name].replace(dtype="float32", unroll=True, remat="none",
                              attn_chunk=128, ssm_chunk=64, **kw)
    m = build_model(cfg)
    B, S = 2, 512
    params = jax.eval_shape(lambda: m.init(jax.random.key(0)))
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    compiled = jax.jit(m.loss).lower(params, batch).compile()
    hlo_flops = roofline.hlo_cost_analysis(compiled).get("flops", 0.0)
    shape = ShapeConfig("v", S, B, "train")
    ana = roofline.analytic_flops(cfg, shape, segments_for(cfg))
    ratio = ana["fwd_total"] / hlo_flops
    assert 0.75 <= ratio <= 1.25, ratio


def test_active_params_moe():
    arch = ARCHS["olmoe-1b-7b"]
    n = build_model(arch).param_count()
    na = roofline.active_params(arch, n)
    assert na < n
    # top-8 of 64 experts: expert block shrinks 8x
    assert na / n < 0.5

"""OFL: bit-exact serializability (shared per-point uniforms), acceptance
probability telescoping (App. B.3), and approximation sanity (Lemma 3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import occ_ofl, serial_ofl, point_uniforms, serial_dp_means
from repro.data import dp_stick_breaking_data

LAM = 4.0


def _epoch_index_order(res, n):
    return np.lexsort((np.arange(n), np.asarray(res.epoch_of)))


@pytest.mark.parametrize("pb,seed", [(16, 0), (64, 1), (128, 2)])
def test_serializability_bitexact(pb, seed):
    x, _, _ = dp_stick_breaking_data(512, seed=seed)
    x = jnp.asarray(x)
    key = jax.random.key(seed)
    res = occ_ofl(x, LAM, pb=pb, key=key, k_max=256)
    u = point_uniforms(key, x.shape[0])
    order = _epoch_index_order(res, x.shape[0])
    pool_s, _ = serial_ofl(x[order], u[order], LAM, 256)
    k = int(res.pool.count)
    assert int(pool_s.count) == k
    np.testing.assert_array_equal(np.asarray(pool_s.centers[:k]),
                                  np.asarray(res.pool.centers[:k]))


def test_acceptance_probability_telescopes():
    """Net acceptance prob equals min(1, d*^2/lam^2) — empirically: OCC OFL
    opens the same number of facilities as serial OFL on average."""
    x, _, _ = dp_stick_breaking_data(512, seed=3)
    x = jnp.asarray(x)
    k_occ, k_ser = [], []
    for s in range(10):
        key = jax.random.key(100 + s)
        u = point_uniforms(key, x.shape[0])
        res = occ_ofl(x, LAM, pb=64, key=key, k_max=256)
        pool_s, _ = serial_ofl(x, u, LAM, 256)
        k_occ.append(int(res.pool.count))
        k_ser.append(int(pool_s.count))
    assert abs(np.mean(k_occ) - np.mean(k_ser)) <= 2.0


def test_approximation_sanity():
    """Lemma 3.2 (sanity form): OCC OFL objective within a constant factor
    of the DP-means solution on random-order data."""
    x, _, _ = dp_stick_breaking_data(1024, seed=4)
    x = jnp.asarray(x)
    j_dp = float(serial_dp_means(x, LAM, k_max=256, max_iters=5).objective)
    js = []
    for s in range(5):
        res = occ_ofl(x, LAM, pb=128, key=jax.random.key(s), k_max=512)
        js.append(float(res.objective))
    assert np.mean(js) <= 10.0 * j_dp   # lemma's constant is 68; be tighter


def test_first_epoch_all_sent():
    """Epoch 1 has no centers: everything goes to the validator (the paper's
    no-scaling-in-first-epoch observation for OFL)."""
    x, _, _ = dp_stick_breaking_data(256, seed=5)
    res = occ_ofl(jnp.asarray(x), LAM, pb=64, key=jax.random.key(0), k_max=256)
    assert int(res.stats.proposed[0]) == 64

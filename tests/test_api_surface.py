"""Public API surface snapshot for `repro.serving` (DESIGN.md §17).

The §17 redesign made `submit(Query(...))` + `ServeConfig` THE serving
surface; this test freezes that surface — exported names, constructor
signatures, dataclass/NamedTuple fields with defaults, public methods —
into tests/golden/api_surface_serving.json so an accidental signature
drift (a renamed field, a default flip, a dropped export) fails loudly
instead of silently breaking downstream callers.

Intentional changes regenerate the snapshot:

    PYTHONPATH=src python tests/test_api_surface.py --regen
"""
import dataclasses
import inspect
import json
import os

import repro.serving as serving

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "api_surface_serving.json")


def _members(obj) -> dict:
    """Public methods/properties defined ON the class (inherited tuple /
    object plumbing excluded — it isn't part of our surface)."""
    out = {}
    for name, val in sorted(vars(obj).items()):
        if name.startswith("_"):
            continue
        if isinstance(val, property):
            out[name] = "<property>"
        elif isinstance(val, (staticmethod, classmethod)):
            out[name] = str(inspect.signature(val.__func__))
        elif callable(val):
            out[name] = str(inspect.signature(val))
    return out


def _describe(obj) -> dict:
    if dataclasses.is_dataclass(obj):
        return {"kind": "dataclass",
                "fields": [[f.name,
                            "<required>"
                            if f.default is dataclasses.MISSING
                            else repr(f.default)]
                           for f in dataclasses.fields(obj)],
                "members": _members(obj)}
    if isinstance(obj, type) and issubclass(obj, tuple) \
            and hasattr(obj, "_fields"):
        return {"kind": "namedtuple",
                "fields": list(obj._fields),
                "defaults": {k: repr(v)
                             for k, v in obj._field_defaults.items()}}
    if isinstance(obj, type):
        return {"kind": "class",
                "init": str(inspect.signature(obj.__init__)),
                "members": _members(obj)}
    return {"kind": "function", "signature": str(inspect.signature(obj))}


def surface() -> dict:
    return {"exports": sorted(serving.__all__),
            "api": {name: _describe(getattr(serving, name))
                    for name in sorted(serving.__all__)}}


def test_serving_api_surface_matches_golden():
    with open(GOLDEN) as f:
        want = json.load(f)
    got = json.loads(json.dumps(surface()))    # normalize tuples -> lists
    assert got == want, (
        "repro.serving public API drifted from tests/golden/"
        "api_surface_serving.json — if the change is intentional, rerun "
        "`PYTHONPATH=src python tests/test_api_surface.py --regen`")


def test_every_export_exists_and_is_public():
    for name in serving.__all__:
        assert not name.startswith("_")
        assert hasattr(serving, name)


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            json.dump(surface(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"regenerated {GOLDEN}")

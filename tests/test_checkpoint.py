"""CheckpointManager + DeltaWAL tests (§7, §14).

The manager ships with the WAL depending on it, so both layers are pinned
here: atomic save/restore, keep-k GC, async writes, corrupt-checkpoint
tolerance; then the WAL's append/checkpoint/replay cycle including torn
tails and the headline crash-resume bit-identity.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.wal import DeltaWAL, WireTee, recover_wal
from repro.core import DPMeansTransaction, OCCEngine
from repro.core.occ import CenterPool
from repro.data import dp_stick_breaking_data
from repro.distributed.transport import store_digest
from repro.serving.snapshot import SnapshotStore

LAM = 4.0


def _pool(rows: np.ndarray, k_max: int = 16) -> CenterPool:
    rows = np.asarray(rows, np.float32)
    k = rows.shape[0]
    c = jnp.zeros((k_max, rows.shape[1]), jnp.float32).at[:k].set(rows)
    return CenterPool(c, jnp.arange(k_max) < k,
                      jnp.asarray(k, jnp.int32), jnp.asarray(False))


def _publish_chain(store, n, rng, k_max=64):
    """n genuinely append-only versions (1 new row each)."""
    base = rng.normal(size=(n, 4)).astype(np.float32)
    for k in range(1, n + 1):
        store.publish_pool(_pool(base[:k], k_max=k_max))


# --------------------------------------------------------- CheckpointManager

def test_save_restore_roundtrip_nested_tree(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"pool": {"centers": np.arange(12, dtype=np.float32).reshape(3, 4),
                     "count": np.asarray(3, np.int32)},
            "flags": [np.array([True, False]), np.asarray(2.5, np.float32)]}
    path = mgr.save(7, tree, extra={"note": "x"})
    assert os.path.isdir(path)
    step, back = mgr.restore(tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(back["pool"]["centers"]),
                                  tree["pool"]["centers"])
    assert int(back["pool"]["count"]) == 3
    np.testing.assert_array_equal(np.asarray(back["flags"][0]),
                                  tree["flags"][0])
    assert float(back["flags"][1]) == 2.5
    assert mgr.manifest(7)["extra"] == {"note": "x"}


def test_restore_rejects_missing_leaf(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": np.zeros(2)})
    with pytest.raises(KeyError, match="missing leaf"):
        mgr.restore({"a": np.zeros(2), "b": np.zeros(2)})
    with pytest.raises(FileNotFoundError):
        CheckpointManager(str(tmp_path / "empty")).restore({"a": np.zeros(2)})


def test_keep_gc_prunes_oldest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"a": np.full(3, s, np.float32)})
    assert mgr.all_steps() == [3, 4]
    step, back = mgr.restore({"a": np.zeros(3)})
    assert step == 4 and float(back["a"][0]) == 4.0


def test_async_write_overlaps_and_waits(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    a = np.arange(8, dtype=np.float32)
    mgr.save(1, {"a": a})
    a = a + 100.0                     # mutate AFTER save: must not leak in
    mgr.save(2, {"a": a})             # implicit wait() for the first write
    mgr.wait()
    assert mgr.all_steps() == [1, 2]
    _, t1 = mgr.restore({"a": np.zeros(8)}, step=1)
    np.testing.assert_array_equal(np.asarray(t1["a"]),
                                  np.arange(8, dtype=np.float32))


def test_latest_step_tolerates_corruption(tmp_path):
    """Satellite: torn/garbage checkpoint dirs must not shadow the last
    good image — `latest_step` sees only checkpoints whose manifest
    parses."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": np.zeros(2)})
    # a crash mid-write leaves a .tmp dir: ignored
    os.makedirs(tmp_path / "step_00000002.tmp")
    # a dir with a torn manifest: ignored
    os.makedirs(tmp_path / "step_00000003")
    with open(tmp_path / "step_00000003" / "manifest.json", "w") as f:
        f.write('{"step": 3, "lea')
    # a dir with NO manifest at all: ignored
    os.makedirs(tmp_path / "step_00000004")
    # an unrelated dir: ignored
    os.makedirs(tmp_path / "step_nonsense")
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1
    step, _ = mgr.restore({"a": np.zeros(2)})
    assert step == 1


# ------------------------------------------------------------------ DeltaWAL

def test_wal_recover_bit_identical_and_version_continuity(tmp_path):
    wal = DeltaWAL(str(tmp_path), model="m", checkpoint_every=0, fsync=False)
    store = SnapshotStore(capacity=32, delta=True, model="m", wire=wal)
    rng = np.random.default_rng(0)
    _publish_chain(store, 10, rng)
    wal.close()
    rec, info = recover_wal(str(tmp_path), model="m", capacity=32)
    assert info == dict(ckpt_version=0, n_replayed=10, n_skipped=0)
    assert rec.latest_meta().version == 10
    assert store_digest(rec) == store_digest(store)
    # version numbering continues — a recovered store can become the new
    # primary without colliding with already-replicated versions
    snap = rec.publish_pool(_pool(rng.normal(size=(11, 4)), k_max=64))
    assert snap.version == 11


def test_wal_checkpoint_cadence_bounds_replay(tmp_path):
    wal = DeltaWAL(str(tmp_path), model="m", checkpoint_every=4, fsync=False)
    store = SnapshotStore(capacity=32, delta=True, model="m", wire=wal)
    rng = np.random.default_rng(1)
    _publish_chain(store, 10, rng)
    assert wal.n_checkpoints == 2 and wal.ckpt.all_steps() == [4, 8]
    wal.close()
    rec, info = recover_wal(str(tmp_path), model="m", capacity=32)
    # replay work is bounded by one checkpoint interval: only 9, 10 replay
    assert info["ckpt_version"] == 8 and info["n_replayed"] == 2
    assert store_digest(rec) == store_digest(store)
    # metadata survives the checkpoint image, not just rows
    assert rec.latest_meta().n_seen == store.latest_meta().n_seen


def test_wal_torn_tail_recovers_last_complete_frame(tmp_path):
    wal = DeltaWAL(str(tmp_path), model="m", checkpoint_every=0, fsync=False)
    store = SnapshotStore(capacity=32, delta=True, model="m", wire=wal)
    rng = np.random.default_rng(2)
    _publish_chain(store, 6, rng)
    wal.close()
    seg = os.path.join(str(tmp_path), "seg_00000000.log")
    size = os.path.getsize(seg)
    with open(seg, "r+b") as f:
        f.truncate(size - 7)          # crash mid-append: torn last frame
    rec, info = recover_wal(str(tmp_path), model="m")
    assert rec.latest_meta().version == 5      # the torn v6 is dropped
    assert info["n_replayed"] == 5
    # garbage appended past a good tail is also tolerated
    with open(seg, "ab") as f:
        f.write(b"\x00garbage-not-a-frame-header\xff" * 3)
    rec2, info2 = recover_wal(str(tmp_path), model="m")
    assert rec2.latest_meta().version == 5
    assert store_digest(rec2) == store_digest(rec)


def test_wal_segment_gc_follows_checkpoint_keep(tmp_path):
    wal = DeltaWAL(str(tmp_path), model="m", checkpoint_every=2, keep=2,
                   fsync=False)
    store = SnapshotStore(capacity=64, delta=True, model="m", wire=wal)
    rng = np.random.default_rng(3)
    _publish_chain(store, 12, rng)
    # checkpoints kept: [10, 12]; live segments must not predate step 10
    assert wal.ckpt.all_steps() == [10, 12]
    assert all(b >= 10 for b in wal.segment_bases())
    wal.close()
    rec, _ = recover_wal(str(tmp_path), model="m")
    assert store_digest(rec) == store_digest(store)


def test_wal_rejects_foreign_model(tmp_path):
    wal = DeltaWAL(str(tmp_path), model="m", fsync=False)
    store = SnapshotStore(capacity=8, delta=True, model="other", wire=wal)
    with pytest.raises(ValueError, match="WAL for 'm'"):
        store.publish_pool(_pool(np.ones((2, 4))))
    wal.close()


def test_wire_tee_fans_out_to_wal_and_followers(tmp_path):
    """One publish stream → socket followers AND the durable log."""
    from repro.distributed.replication import DeltaChannel, make_follower
    wal = DeltaWAL(str(tmp_path), model="m", checkpoint_every=0, fsync=False)
    chan = DeltaChannel()
    follower = make_follower(chan, "m", capacity=8)
    store = SnapshotStore(capacity=8, delta=True, model="m",
                          wire=WireTee(chan, wal))
    rng = np.random.default_rng(4)
    _publish_chain(store, 3, rng, k_max=16)
    chan.pump()
    wal.close()
    rec, _ = recover_wal(str(tmp_path), model="m")
    assert (store_digest(follower) == store_digest(rec)
            == store_digest(store))


def test_trainer_crash_wal_replay_resumes_bit_identical(tmp_path):
    """Acceptance: WAL replay after a simulated trainer crash restores the
    stream bit-identically — the resumed trainer's final pool equals the
    uninterrupted run's, element for element."""
    x = jnp.asarray(dp_stick_breaking_data(1024, 8, seed=5)[0])

    # uninterrupted reference
    ref = OCCEngine(DPMeansTransaction(LAM, k_max=64), pb=64)
    ref.partial_fit(x[:512])
    ref.partial_fit(x[512:])
    ref.flush()

    # trainer publishing every pass through a WAL... then it "crashes"
    wal = DeltaWAL(str(tmp_path), model="m", checkpoint_every=2, fsync=False)
    store = SnapshotStore(capacity=16, delta=True, model="m", wire=wal)
    crashy = OCCEngine(DPMeansTransaction(LAM, k_max=64), pb=64,
                       publish=store.publish_pass)
    crashy.partial_fit(x[:512])
    wal.close()                        # process dies here; only disk remains

    rec, info = recover_wal(str(tmp_path), model="m", capacity=16)
    assert store_digest(rec) == store_digest(store)
    snap = rec.latest().materialize()
    assert snap.n_seen == 512          # resume point == published watermark

    resumed = OCCEngine(DPMeansTransaction(LAM, k_max=64), pb=64)
    resumed.restore(snap, k_max=64)
    resumed.partial_fit(x[snap.n_seen:])
    resumed.flush()
    assert int(resumed.pool.count) == int(ref.pool.count)
    np.testing.assert_array_equal(np.asarray(resumed.pool.centers),
                                  np.asarray(ref.pool.centers))

"""Sharding-rule units: divisibility fallbacks, param spec table, shapes."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, reduced, supports_shape
from repro.distributed.shardings import (
    ShardCtx, axes_that_divide, batch_spec, param_specs, shard_ctx, spec_for)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def _ctx(shape=None):
    return ShardCtx(mesh=FakeMesh(shape or {"pod": 2, "data": 16, "model": 16}))


def test_axes_that_divide():
    ctx = _ctx()
    assert axes_that_divide(256, ("pod", "data"), ctx) == ("pod", "data")
    assert axes_that_divide(2, ("pod", "data"), ctx) == ("pod",)
    assert axes_that_divide(1, ("pod", "data"), ctx) == ()
    assert axes_that_divide(8, ("model",), ctx) == ()     # 8 % 16 != 0
    assert axes_that_divide(32, ("model",), ctx) == ("model",)


def test_batch_spec_fallbacks():
    assert batch_spec(256, _ctx()) == ("pod", "data")
    assert batch_spec(2, _ctx()) == ("pod",)
    assert batch_spec(1, _ctx()) is None
    assert batch_spec(7, _ctx()) is None


def test_spec_for_kv_head_replication():
    ctx = _ctx()
    # kv_heads=8 on model=16 -> replicated (Megatron GQA fallback)
    spec = spec_for((256, 4096, 8, 128), (("pod", "data"), None, "model", None), ctx)
    assert spec == P(("pod", "data"), None, None, None)
    spec = spec_for((256, 4096, 32, 128), (("pod", "data"), None, "model", None), ctx)
    assert spec == P(("pod", "data"), None, "model", None)
    # batch=2 only divides the pod axis
    spec = spec_for((2, 4096, 32, 128), (("pod", "data"), None, "model", None), ctx)
    assert spec == P("pod", None, "model", None)


def test_param_specs_rules():
    import jax.numpy as jnp
    params = {
        "tok_embed": jax.ShapeDtypeStruct((49152, 2048), jnp.float32),
        "segments": {"seg_00": {
            "wq": jax.ShapeDtypeStruct((40, 2048, 2048), jnp.float32),
            "norm1": jax.ShapeDtypeStruct((40, 2048), jnp.float32),
            "we_g": jax.ShapeDtypeStruct((16, 16, 2048, 6400), jnp.float32),
        }},
    }
    ctx = _ctx()
    specs = param_specs(params, ctx)
    assert specs["tok_embed"] == P("model", ("pod", "data"))
    seg = specs["segments"]["seg_00"]
    assert seg["wq"] == P(None, ("pod", "data"), "model")
    assert seg["norm1"] == P(None, None)
    assert seg["we_g"] == P(None, "model", ("pod", "data"), None)


def test_supports_shape_matrix():
    """The assigned 40-cell matrix: long_500k only for subquadratic archs."""
    runnable = 0
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, _ = supports_shape(arch, shape)
            runnable += ok
    assert runnable == 10 * 3 + 2   # 30 short cells + zamba2/xlstm long


def test_reduced_configs_small():
    for arch in ARCHS.values():
        r = reduced(arch)
        assert r.d_model <= 64 and r.vocab <= 128
        assert r.family == arch.family
        if arch.moe:
            assert r.moe.n_experts <= 4

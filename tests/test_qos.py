"""Mixed-traffic QoS serving plane (DESIGN.md §17).

Contracts under test:
  * lane scheduler (pure) — ready = full-or-deadline per group with
    INDEPENDENT timers; interactive preempts batch/analytics; aging
    credits bound starvation at `aging_limit` passed-over rounds; the
    FIFO baseline keeps head-of-line blocking by construction;
  * shed policy (pure + threaded) — sheds only under overload, only
    non-interactive lanes, only `max_staleness > 0`; degraded responses
    are tagged with their stale pin's version and replay bit-exactly;
  * typed surface — `Query` / `ServeConfig` validation fails fast;
  * close-race (the PR-10 bugfix) — requests admitted before `close()`
    are FLUSHED with real answers, never dropped; submits racing close
    either land in a flushed group or fail fast.

Threaded tests run `backend="ref"` with second-scale latency bounds:
the PRECISION lives in the pure-scheduler tests (explicit clocks), the
threaded ones only pin end-to-end wiring on a 1-CPU worst case.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DPMeansTransaction, OCCEngine, nearest_center
from repro.data import dp_stick_breaking_data
from repro.obs.metrics import Ewma, now as _now
from repro.serving import ClusterService, Query, ServeConfig, SnapshotStore
from repro.serving import qos
from repro.serving.cluster_service import _assign_step, _topk_step
from repro.serving.qos import FlushDecision, LaneState

LAM = 4.0


def _stream(n=768, seed=0, dim=8):
    x, _, _ = dp_stick_breaking_data(n, seed=seed, dim=dim)
    return jnp.asarray(x)


def _trained_store(x, batches=((0, 300), (300, 768))):
    store = SnapshotStore(capacity=64)
    eng = OCCEngine(DPMeansTransaction(LAM, k_max=128), pb=64,
                    publish=store.publish_pass)
    for lo, hi in batches:
        eng.partial_fit(x[lo:hi])
    eng.flush()
    return store, eng


def _st(key, rows, oldest, deadline):
    return LaneState(key, key[2], rows, oldest, deadline)


def _replay(rec, snap, backend="ref"):
    """Replay one DispatchRecord through the service's own jitted steps."""
    if rec.kind == "topk":
        d2, idx = _topk_step(snap.centers, snap.mask, np.int32(snap.count),
                             jnp.asarray(rec.x), np.int32(rec.n_valid),
                             k=rec.k, backend=backend)
    else:
        d2, idx = _assign_step(snap.centers, snap.mask, np.int32(snap.count),
                               jnp.asarray(rec.x), np.int32(rec.n_valid),
                               backend=backend)
    return np.asarray(d2), np.asarray(idx)


IK = ("score", 0, "interactive")
BK = ("score", 0, "batch")
AK = ("topk", 4, "analytics")


# ------------------------------------------------------- lane scheduler

def test_select_flush_nothing_ready():
    states = [_st(IK, 4, 0.0, 10.0), _st(BK, 8, 0.0, 20.0)]
    assert qos.select_flush(states, 5.0, {}, 64, 4) is None


def test_select_flush_full_and_deadline_reasons():
    # full beats the clock; deadline fires exactly at deadline_t
    pick = qos.select_flush([_st(IK, 64, 0.0, 99.0)], 1.0, {}, 64, 4)
    assert pick == FlushDecision(IK, "full", ())
    pick = qos.select_flush([_st(IK, 4, 0.0, 3.0)], 3.0, {}, 64, 4)
    assert pick == FlushDecision(IK, "deadline", ())


def test_select_flush_interactive_preempts_ready_batch():
    # BOTH ready (batch earlier deadline, even full) — interactive still
    # wins on lane rank; batch is recorded as passed over.
    states = [_st(BK, 64, 0.0, 1.0), _st(IK, 4, 2.0, 3.0)]
    pick = qos.select_flush(states, 5.0, {}, 64, 4)
    assert pick.key == IK and pick.passed_over == (BK,)


def test_select_flush_deadline_timer_independence():
    # A stalled batch group whose long deadline has NOT expired is
    # invisible to the decision: interactive flushes on its own timer and
    # batch is not even "passed over" (no credit accrues while unready).
    states = [_st(BK, 32, 0.0, 1000.0), _st(IK, 4, 5.0, 6.0)]
    pick = qos.select_flush(states, 6.0, {}, 64, 4)
    assert pick == FlushDecision(IK, "deadline", ())


def test_select_flush_aging_preempts_everything():
    states = [_st(BK, 8, 0.0, 1.0), _st(IK, 4, 2.0, 3.0)]
    pick = qos.select_flush(states, 5.0, {BK: 4}, 64, aging_limit=4)
    assert pick.key == BK and pick.reason == "aged"
    assert pick.passed_over == (IK,)
    # one credit short: interactive still preempts
    pick = qos.select_flush(states, 5.0, {BK: 3}, 64, aging_limit=4)
    assert pick.key == IK


def test_select_flush_same_lane_tiebreak_by_deadline():
    k2 = ("topk", 4, "interactive")
    states = [_st(IK, 4, 0.0, 9.0), _st(k2, 4, 1.0, 7.0)]
    pick = qos.select_flush(states, 10.0, {}, 64, 4)
    assert pick.key == k2 and pick.passed_over == (IK,)


def test_aging_simulation_bounds_starvation():
    # Drive the pure policy round by round the way _AdmissionQueue does:
    # a batch group READY from t=0 under sustained ready-interactive
    # pressure must win by round aging_limit + 1, no later.
    limit, credits = 3, {}
    states = [_st(BK, 8, 0.0, 0.0), _st(IK, 4, 1.0, 1.0)]
    for rnd in range(1, 10):
        pick = qos.select_flush(states, 2.0, credits, 64, limit)
        if pick.key == BK:
            assert pick.reason == "aged" and rnd == limit + 1
            break
        for k in pick.passed_over:
            credits[k] = credits.get(k, 0) + 1
        credits.pop(pick.key, None)
    else:
        pytest.fail("batch lane starved past the aging bound")


def test_select_flush_fifo_head_of_line_blocking():
    # Oldest request belongs to analytics with a far deadline: the FIFO
    # baseline flushes NOTHING, even though interactive expired — the
    # exact head-of-line blocking the lane scheduler removes.
    states = [_st(AK, 8, 0.0, 100.0), _st(IK, 4, 1.0, 2.0)]
    assert qos.select_flush_fifo(states, 50.0, 64) is None
    assert qos.select_flush(states, 50.0, {}, 64, 4).key == IK
    # head past its own deadline (or full) finally flushes
    assert qos.select_flush_fifo(states, 100.0, 64) == \
        FlushDecision(AK, "deadline", ())
    full = [_st(AK, 64, 0.0, 100.0), _st(IK, 4, 1.0, 2.0)]
    assert qos.select_flush_fifo(full, 3.0, 64) == \
        FlushDecision(AK, "full", ())


def test_next_deadline_is_min_over_all_groups():
    assert qos.next_deadline([]) is None
    states = [_st(AK, 8, 0.0, 100.0), _st(IK, 4, 1.0, 2.0)]
    assert qos.next_deadline(states) == 2.0


def test_effective_lane():
    assert qos.effective_lane("analytics", True) == "analytics"
    assert qos.effective_lane("analytics", False) == "interactive"


# ----------------------------------------------------------- shed policy

def test_overload_score_max_of_normalized_terms():
    assert qos.overload_score(0, 512, 0.0, 0.5) == 0.0
    assert qos.overload_score(512, 512, 0.0, 0.5) == 1.0
    assert qos.overload_score(256, 512, 0.25, 0.5) == 0.5
    assert qos.overload_score(128, 512, 0.6, 0.5) == pytest.approx(1.2)


def test_should_shed_matrix():
    # sheds only when: overloaded AND non-interactive AND staleness > 0
    assert qos.should_shed("analytics", 3, 1.0)
    assert qos.should_shed("batch", 1, 2.0)
    assert not qos.should_shed("analytics", 3, 0.99)      # not overloaded
    assert not qos.should_shed("interactive", 3, 5.0)     # interactive
    assert not qos.should_shed("analytics", 0, 5.0)       # latest-only


# -------------------------------------------------------- typed surface

def test_query_validation_errors():
    x = np.zeros((4, 8), np.float32)
    with pytest.raises(ValueError, match="kind"):
        Query(x, kind="knn")
    with pytest.raises(ValueError, match="k >= 1"):
        Query(x, kind="topk")
    with pytest.raises(ValueError, match="k == 0"):
        Query(x, kind="score", k=3)
    with pytest.raises(ValueError, match="priority"):
        Query(x, priority="realtime")
    with pytest.raises(ValueError, match="deadline_ms"):
        Query(x, deadline_ms=0.0)
    with pytest.raises(ValueError, match="max_staleness"):
        Query(x, max_staleness=-1)
    with pytest.raises(ValueError, match="max_staleness"):
        Query(x, max_staleness=1.5)


def test_serve_config_validation_and_lane_delays():
    with pytest.raises(ValueError, match="power of two"):
        ServeConfig(coalesce_bucket=48)
    with pytest.raises(ValueError, match="coalesce_delay_ms"):
        ServeConfig(coalesce_delay_ms=0.0)
    with pytest.raises(ValueError, match="aging_limit"):
        ServeConfig(aging_limit=0)
    with pytest.raises(ValueError, match="shed"):
        ServeConfig(shed_depth=0)
    cfg = ServeConfig(coalesce_delay_ms=2.0)
    # derived lane budgets: batch 8x, analytics 16x the interactive one
    assert cfg.lane_delay_s("interactive") == pytest.approx(0.002)
    assert cfg.lane_delay_s("batch") == pytest.approx(0.016)
    assert cfg.lane_delay_s("analytics") == pytest.approx(0.032)
    # explicit overrides win; miss grace defaults to the lane budget
    cfg2 = cfg.replace(batch_delay_ms=5.0, miss_grace_ms=1.0)
    assert cfg2.lane_delay_s("batch") == pytest.approx(0.005)
    assert cfg2.miss_grace_s("analytics") == pytest.approx(0.001)
    assert cfg.miss_grace_s("batch") == cfg.lane_delay_s("batch")
    assert cfg.replace() == cfg


def test_ewma_seeds_exactly_then_decays():
    e = Ewma(alpha=0.5)
    assert e.value == 0.0 and e.count == 0
    e.observe(1.0)
    assert e.value == 1.0          # first observation seeds, no 0-bias
    e.observe(0.0)
    assert e.value == pytest.approx(0.5)
    assert e.count == 2


# ------------------------------------------------- threaded service QoS

def test_service_deadline_timer_independence():
    """A queued analytics request with a multi-second deadline must not
    delay an interactive flush; close() then dispatches the analytics
    group (flush-not-drop) instead of letting it wait out its budget."""
    x = _stream()
    store, _ = _trained_store(x)
    svc = ClusterService(store, backend="ref", coalesce=True,
                         coalesce_bucket=64, coalesce_delay_ms=50.0,
                         analytics_delay_ms=30_000.0, audit_log=True)
    try:
        out = {}

        def analytics():
            out["a"] = svc.submit(Query(x[:16], kind="topk", k=4,
                                        priority="analytics",
                                        max_staleness=2))
        th = threading.Thread(target=analytics)
        th.start()
        t0 = _now()
        while svc.queue_depth_rows() == 0 and _now() - t0 < 5.0:
            pass                      # analytics admitted and parked
        t0 = _now()
        resp = svc.submit(Query(x[:4]))
        dt = _now() - t0
        assert resp.group >= 0 and not resp.degraded
        # seconds-scale bound (1-CPU noise floor) — still far below the
        # 30 s analytics budget a blocking head would have cost us.
        assert dt < 5.0, f"interactive flush waited {dt:.2f}s"
        assert svc.queue_depth_rows() >= 16   # analytics still parked
    finally:
        svc.close()
    th.join(timeout=10)
    assert not th.is_alive() and out["a"].group >= 0
    lf = svc.metrics()["lane_flushes"]
    assert any(key.startswith("interactive/") for key in lf)
    assert lf.get("analytics/close", 0) == 1   # drained on the way down


def test_service_priority_aging_drains_batch_under_load():
    """One batch request under a sustained stream of interactive traffic
    completes anyway (aging credit), while the flood is still running."""
    x = _stream()
    store, _ = _trained_store(x)
    svc = ClusterService(store, backend="ref", coalesce=True,
                         coalesce_bucket=64, coalesce_delay_ms=10.0,
                         batch_delay_ms=20.0, aging_limit=2)
    try:
        done = threading.Event()

        def batch():
            svc.submit(Query(x[:8], priority="batch"))
            done.set()
        th = threading.Thread(target=batch)
        th.start()
        t0 = _now()
        while not done.is_set() and _now() - t0 < 30.0:
            svc.submit(Query(x[:4]))          # sustained interactive load
        assert done.is_set(), "batch lane starved behind interactive flood"
        th.join(timeout=10)
        lf = svc.metrics()["lane_flushes"]
        assert sum(v for key, v in lf.items()
                   if key.startswith("batch/")) >= 1
    finally:
        svc.close()


def test_shed_path_degrades_and_replays_bit_exact():
    """Forced overload (external shed signal): sheddable traffic degrades
    to the stale pin and replays bit-exactly; interactive and latest-only
    traffic is NEVER shed, whatever the signal says."""
    x = _stream()
    store, _ = _trained_store(x)
    svc = ClusterService(store, backend="ref", coalesce=True,
                         coalesce_bucket=32, coalesce_delay_ms=10.0,
                         audit_log=True, shed_signal=lambda: 2.0)
    try:
        r_an = svc.submit(Query(x[:8], kind="topk", k=4,
                                priority="analytics", max_staleness=3))
        assert r_an.degraded and r_an.group == -1
        r_ba = svc.submit(Query(x[8:16], priority="batch", max_staleness=1))
        assert r_ba.degraded
        # never shed: interactive (even staleness-tolerant), latest-only
        r_in = svc.submit(Query(x[:8], max_staleness=5))
        assert not r_in.degraded and r_in.group >= 0
        r_b0 = svc.submit(Query(x[:8], priority="batch", max_staleness=0))
        assert not r_b0.degraded and r_b0.group >= 0
        m = svc.metrics()
        assert m["n_shed"] == {"interactive": 0, "batch": 1, "analytics": 1}
        assert m["overload_score"] >= 2.0
        # degraded responses replay bit-exactly from their tagged version
        deg = [r for r in svc.audit if r.degraded]
        assert len(deg) == 2
        for rec, resp in zip(deg, (r_an, r_ba)):
            assert rec.version == resp.version
            d2, idx = _replay(rec, store.get(rec.version))
            n = rec.n_valid
            np.testing.assert_array_equal(idx[:n], resp.labels)
            np.testing.assert_array_equal(d2[:n], resp.scores)
    finally:
        svc.close()


def test_stale_pin_held_then_repinned_on_drift():
    """The shed pin is HELD across sheds (stable degraded version) and
    re-pinned only when it drifts past the caller's tolerance."""
    x = _stream()
    store, eng = _trained_store(x, batches=((0, 200),))
    svc = ClusterService(store, backend="ref", coalesce=True,
                         coalesce_bucket=32, coalesce_delay_ms=10.0,
                         shed_signal=lambda: 2.0)
    try:
        v0 = svc.submit(Query(x[:4], kind="topk", k=4, priority="analytics",
                              max_staleness=8)).version
        assert v0 == store.latest().version
        eng.partial_fit(x[200:500])          # advance published versions
        eng.partial_fit(x[500:768])
        eng.flush()
        drift = store.latest().version - v0
        assert drift >= 2
        # within tolerance: pin held — the degraded version is STALE
        r = svc.submit(Query(x[:4], kind="topk", k=4, priority="analytics",
                             max_staleness=drift + 1))
        assert r.degraded and r.version == v0 < store.latest().version
        # tolerance tightened past the drift: re-pin to latest
        r = svc.submit(Query(x[:4], kind="topk", k=4, priority="analytics",
                             max_staleness=1))
        assert r.degraded and r.version == store.latest().version
    finally:
        svc.close()


# ----------------------------------------------------------- close race

def test_close_flushes_pending_requests():
    """The PR-10 bugfix pin: requests admitted before close() get REAL
    answers (bit-identical to solo serving), not errors or drops."""
    x = _stream()
    store, _ = _trained_store(x)
    svc = ClusterService(store, backend="ref", coalesce=True,
                         coalesce_bucket=64, coalesce_delay_ms=60_000.0)
    ref = ClusterService(store, backend="ref")
    outs, errs = {}, {}

    def client(i, lo, hi):
        try:
            outs[i] = svc.submit(Query(x[lo:hi], deadline_ms=60_000.0))
        except Exception as e:            # noqa: BLE001 — recorded for assert
            errs[i] = e
    spans = [(0, 8), (8, 13), (13, 21)]
    threads = [threading.Thread(target=client, args=(i, lo, hi))
               for i, (lo, hi) in enumerate(spans)]
    for th in threads:
        th.start()
    t0 = _now()
    while svc.queue_depth_rows() < 21 and _now() - t0 < 10.0:
        pass
    assert svc.queue_depth_rows() == 21   # all parked on the 60 s timer
    t0 = _now()
    svc.close()
    assert _now() - t0 < 10.0             # drained, not waited out
    for th in threads:
        th.join(timeout=10)
    assert not errs and sorted(outs) == [0, 1, 2]
    for i, (lo, hi) in enumerate(spans):
        assert outs[i].group >= 0 and not outs[i].degraded
        np.testing.assert_array_equal(outs[i].labels,
                                      ref.score(x[lo:hi]).labels)


def test_submit_racing_close_never_hangs():
    """Submits racing close() either land in a flushed group or fail fast
    with 'service closed' — none may hang, none may lose its answer."""
    x = _stream()
    store, _ = _trained_store(x)
    svc = ClusterService(store, backend="ref", coalesce=True,
                         coalesce_bucket=64, coalesce_delay_ms=40.0)
    n_ok, n_closed, bad = [], [], []
    stop = threading.Event()

    def client(i):
        while not stop.is_set():
            try:
                resp = svc.submit(Query(x[i * 4:i * 4 + 4]))
                assert resp.labels.shape == (4,)
                n_ok.append(i)
            except RuntimeError as e:
                assert "service closed" in str(e), e
                n_closed.append(i)
                return
            except Exception as e:        # noqa: BLE001
                bad.append(e)
                return
    threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
    for th in threads:
        th.start()
    t0 = _now()
    while not n_ok and _now() - t0 < 10.0:
        pass                              # at least one flush served
    svc.close()
    stop.set()
    for th in threads:
        th.join(timeout=15)
    assert not any(th.is_alive() for th in threads)
    assert not bad and n_ok
    # after close the service still answers — on the solo path
    resp = svc.score(x[:4])
    assert resp.group == -1 and not resp.degraded

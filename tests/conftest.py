# NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 device.
# Multi-device tests spawn subprocesses with their own flags.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption(
        "--inject-seed", type=int, default=0,
        help="seed for probabilistic fault-injection schedules — the CI "
             "chaos job sweeps several so convergence claims are not "
             "overfitted to one lucky schedule")


@pytest.fixture
def inject_seed(request):
    return request.config.getoption("--inject-seed")

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 device.
# Multi-device tests spawn subprocesses with their own flags.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)

"""End-to-end integration: training converges, checkpoint resume is exact,
serving engine agrees with the teacher-forced model, OCC curation runs
inside the framework."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, TrainConfig, reduced
from repro.data.tokens import TokenPipeline
from repro.models import build_model
from repro.serving.engine import Request, ServeEngine
from repro.training.step import make_train_step, train_state_init


def _tiny(name="granite-3-2b"):
    return reduced(ARCHS[name]).replace(dtype="float32")


def test_train_loss_decreases():
    cfg = _tiny()
    m = build_model(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=3, total_steps=30)
    state = train_state_init(m.init(jax.random.key(0)), tcfg)
    step = jax.jit(make_train_step(m, tcfg))
    pipe = TokenPipeline(cfg.vocab, 8, 32, seed=0)
    losses = []
    for s in range(30):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_microbatch_equivalence():
    """Grad accumulation over microbatches == one big batch (same data)."""
    cfg = _tiny()
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    pipe = TokenPipeline(cfg.vocab, 8, 16, seed=1)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    s1 = train_state_init(params, TrainConfig(microbatches=1))
    s2 = train_state_init(params, TrainConfig(microbatches=4))
    st1, m1 = make_train_step(m, TrainConfig(microbatches=1))(s1, batch)
    st2, m2 = make_train_step(m, TrainConfig(microbatches=4))(s2, batch)
    # microbatched loss averages per-microbatch means -> equal here since
    # chunks are equally sized
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    a = jax.tree.leaves(st1.params)[0]
    b = jax.tree.leaves(st2.params)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_checkpoint_resume_exact(tmp_path):
    """Fault tolerance: kill after step k, restore, continue — identical
    final state to an uninterrupted run (deterministic pipeline + step)."""
    cfg = _tiny()
    m = build_model(cfg)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    pipe = TokenPipeline(cfg.vocab, 4, 16, seed=2)
    step = jax.jit(make_train_step(m, tcfg))

    def run(n0, n1, state):
        for s in range(n0, n1):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            state, _ = step(state, batch)
        return state

    state_a = train_state_init(m.init(jax.random.key(0)), tcfg)
    state_a = run(0, 10, state_a)

    state_b = train_state_init(m.init(jax.random.key(0)), tcfg)
    state_b = run(0, 5, state_b)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, state_b)
    # "crash"; restore into fresh structure
    fresh = train_state_init(m.init(jax.random.key(0)), tcfg)
    step_restored, state_c = mgr.restore(fresh)
    assert step_restored == 5
    state_c = run(5, 10, state_c)

    for a, c in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_c.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-6)


def test_serving_engine_matches_model():
    """Greedy engine output == manual prefill+greedy decode."""
    cfg = _tiny()
    m = build_model(cfg)
    params = m.init(jax.random.key(3))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 8)
    eng = ServeEngine(m, params, n_slots=2, cache_len=64)
    req = Request(uid=0, prompt=prompt, max_new=6)
    done = eng.run([req])
    assert len(done) == 1 and len(done[0].out) == 6

    # manual: feed prompt token-by-token through decode_step on batch of 1
    caches = m.init_cache(1, 64)
    pos = jnp.zeros((1,), jnp.int32)
    for t in prompt:
        logits, caches = m.decode_step(params, caches,
                                       jnp.asarray([[t]], jnp.int32), pos)
        pos = pos + 1
    outs = []
    tok = int(jnp.argmax(logits[0]))
    for _ in range(6):
        outs.append(tok)
        logits, caches = m.decode_step(params, caches,
                                       jnp.asarray([[tok]], jnp.int32), pos)
        pos = pos + 1
        tok = int(jnp.argmax(logits[0]))
    assert done[0].out == outs


def test_slot_recycling_more_requests_than_slots():
    cfg = _tiny()
    m = build_model(cfg)
    params = m.init(jax.random.key(4))
    rng = np.random.default_rng(4)
    eng = ServeEngine(m, params, n_slots=2, cache_len=48)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 4), max_new=4)
            for i in range(5)]
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.out) == 4 for r in done)

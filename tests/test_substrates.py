"""Substrate units: optimizer, compression, checkpoint, elastic, fault,
data pipeline, curation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.curation import curate
from repro.data.synthetic import dp_stick_breaking_data
from repro.data.tokens import TokenPipeline
from repro.distributed.elastic import plan_shrunk_mesh, build_mesh_from_plan
from repro.distributed.fault import HeartbeatTracker, StepWatchdog
from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               cosine_lr, global_norm)
from repro.optim.compression import (apply_error_feedback, compress_int8,
                                     decompress_int8, ef_init)


# ---------------------------------------------------------------- optimizer

def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = adamw_init(params)
    target = jnp.asarray([1.0, 2.0, -1.0])
    for step in range(300):
        grads = {"w": params["w"] - target}
        params, state = adamw_update(params, grads, state, lr=0.05,
                                     weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_cosine_lr_schedule():
    assert float(cosine_lr(0, 1.0, warmup=10, total=100)) == pytest.approx(0.1)
    assert float(cosine_lr(9, 1.0, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(cosine_lr(99, 1.0, warmup=10, total=100)) <= 0.15


def test_clip_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# -------------------------------------------------------------- compression

def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, s = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_error_feedback_telescopes():
    """With EF, the *cumulative* applied update tracks the cumulative true
    gradient: residual stays bounded, bias telescopes to zero."""
    rng = np.random.default_rng(1)
    grads_seq = [{"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
                 for _ in range(50)]
    ef = ef_init(grads_seq[0])
    applied = jnp.zeros(64)
    true = jnp.zeros(64)
    for g in grads_seq:
        dec, ef = apply_error_feedback(g, ef)
        applied = applied + dec["w"]
        true = true + g["w"]
    resid = np.asarray(ef.residual["w"])
    np.testing.assert_allclose(np.asarray(applied + resid), np.asarray(true),
                               rtol=1e-4, atol=1e-4)
    assert np.abs(resid).max() < 0.1   # bounded by one quantization step


# --------------------------------------------------------------- checkpoint

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(10, t)
    step, restored = mgr.restore(jax.eval_shape(lambda: t))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))
    np.testing.assert_array_equal(np.asarray(restored["nested"]["b"]),
                                  np.asarray(t["nested"]["b"]))


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(5, _tree())
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_missing_leaf_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        mgr.restore({"a": jnp.zeros(3), "extra": jnp.zeros(2)})


# ------------------------------------------------------------------ elastic

def test_elastic_plan_shrinks_data_axis():
    from repro.launch.mesh import compat_mesh
    mesh = compat_mesh((1,), ("data",))

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    plan = plan_shrunk_mesh(FakeMesh(), n_failed=3)
    # 3 failures with 32 devices per data rank -> lose 1 data rank
    assert plan.new_shape == {"pod": 2, "data": 15, "model": 16}
    plan0 = plan_shrunk_mesh(FakeMesh(), n_failed=0)
    assert plan0.new_shape["data"] == 16


def test_elastic_too_many_failures():
    class FakeMesh:
        shape = {"data": 2, "model": 2}
    with pytest.raises(RuntimeError):
        plan_shrunk_mesh(FakeMesh(), n_failed=4)


# -------------------------------------------------------------------- fault

def test_watchdog_flags_straggler():
    wd = StepWatchdog(threshold=2.0, warmup_steps=2)
    events = [wd.observe(i, 1.0) for i in range(8)]
    assert all(e is None for e in events)
    ev = wd.observe(9, 5.0)
    assert ev is not None and ev.ratio > 2.0
    # outlier not folded into ewma
    assert wd.ewma == pytest.approx(1.0, rel=0.05)


def test_heartbeat_dead_hosts():
    hb = HeartbeatTracker(timeout=10.0)
    hb.beat(0, now=0.0)
    hb.beat(1, now=5.0)
    assert hb.dead_hosts(now=12.0) == [0]


# --------------------------------------------------------------------- data

def test_token_pipeline_deterministic_and_restartable():
    p = TokenPipeline(1000, global_batch=4, seq_len=8, seed=3)
    b1 = p.batch_at(7)
    b2 = p.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert b1["tokens"].max() < 1000


def test_token_pipeline_host_sharding():
    full = TokenPipeline(100, 4, 8, seed=0)
    h0 = TokenPipeline(100, 4, 8, seed=0, host_index=0, host_count=2)
    h1 = TokenPipeline(100, 4, 8, seed=0, host_index=1, host_count=2)
    assert h0.host_batch == 2 and h1.host_batch == 2
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


def test_curation_downweights_duplicates():
    x, z, _ = dp_stick_breaking_data(512, seed=0)
    # inject near-duplicates
    x[:100] = x[0] + 0.01 * np.random.default_rng(0).normal(size=(100, 16))
    rep = curate(jnp.asarray(x), lam=4.0, pb=64, k_max=128)
    assert rep.n_clusters >= 1
    assert rep.keep_weight.min() < 1.0       # the duplicate cluster got capped
    assert rep.keep_weight.max() <= 1.0
